//! The serve-mode coordinator: a WAL-backed [`OpenLoop`] plus the
//! submission/replay logic around it. Everything here is
//! single-threaded — the server runs one `Service` on a dedicated sim
//! thread and feeds it commands over a channel (`serve/server.rs`);
//! the tests drive it directly.
//!
//! Determinism contract: a job's outcome is a pure function of the
//! sequence of [`OpenLoop`] calls — pushes (spec, arrival-stamp bits,
//! weight) and advance targets. `Service` therefore writes a WAL
//! record *before* every such call (see `serve/wal.rs`) and replays
//! the log on resume, landing in bitwise-identical state. Job DAGs are
//! never serialized: the WAL stores the submission JSON, and replay
//! re-runs the same scheduler plan + expansion — same spec, same code,
//! same DAG.
//!
//! Policy pinning: the era engine runs ONE sharing policy for every
//! live job, so the service pins it from the configured scheduler name
//! (`mxdag`/`packing` → priority, `fair` → fair, `fifo` → fifo,
//! `coflow` → coflow). A submission may name its own `scheduler` only
//! if it pins the *same* policy (it still gets its own annotation
//! plan); otherwise the submission is refused with a 400. The
//! `MxScheduler`'s occasional fair-policy fallback plan is overridden
//! by the pinned policy for the same reason.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::mxdag::MXDag;
use crate::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Scheduler,
};
use crate::sim::{
    expand, AllocKind, Cluster, HorizonKind, JobOutcome, OpenConfig, OpenJob, OpenLoop, Policy,
    QueueKind, SimConfig, SimScratch,
};
use crate::util::json::{f64_bits_hex, f64_from_bits_hex, Json};

use super::wal::{self, Wal};

/// Instantiate a scheduler by its CLI name (the same registry as
/// `mxdag simulate --scheduler`, but with unknown names rejected
/// instead of defaulting — a server must not guess).
pub fn scheduler_by_name(name: &str) -> Result<Box<dyn Scheduler>, String> {
    match name {
        "fair" => Ok(Box::new(FairScheduler)),
        "fifo" => Ok(Box::new(FifoScheduler)),
        "packing" => Ok(Box::new(PackingScheduler)),
        "coflow" => Ok(Box::new(CoflowScheduler::new(Grouping::ByDst))),
        "mxdag" => Ok(Box::new(MxScheduler::default())),
        other => Err(format!(
            "unknown scheduler `{other}` (mxdag|fair|fifo|packing|coflow)"
        )),
    }
}

/// The engine policy a scheduler name pins (see module docs).
pub fn pinned_policy(name: &str) -> Result<Policy, String> {
    match name {
        "fair" => Ok(Policy::fair()),
        "fifo" => Ok(Policy::fifo()),
        "coflow" => Ok(Policy::coflow()),
        "mxdag" | "packing" => Ok(Policy::priority()),
        other => Err(format!(
            "unknown scheduler `{other}` (mxdag|fair|fifo|packing|coflow)"
        )),
    }
}

/// Serve configuration. The determinism-relevant part (everything but
/// `snap_every`) is persisted in the WAL `open` record / snapshot and
/// wins over CLI flags on resume — changing the cluster or engine
/// under a half-replayed log would silently change every outcome.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub cluster: Cluster,
    /// Default scheduler name; also pins the engine policy.
    pub scheduler: String,
    /// Admission watermark (estimated drain time above which arrivals
    /// are refused or deferred).
    pub watermark: f64,
    /// How long a refused arrival may wait in the deferral queue.
    pub defer_max: f64,
    /// Era-engine configuration; `policy` is overwritten with the
    /// pinned one.
    pub engine: SimConfig,
    /// Per-tenant deferral weights (absent tenants weigh 1).
    pub weights: BTreeMap<String, i64>,
    /// Snapshot + truncate the WAL every this many records
    /// (operational, not persisted).
    pub snap_every: usize,
}

impl ServeConfig {
    pub fn new(cluster: Cluster, scheduler: &str) -> Result<ServeConfig, String> {
        let policy = pinned_policy(scheduler)?;
        Ok(ServeConfig {
            cluster,
            scheduler: scheduler.to_string(),
            watermark: f64::INFINITY,
            defer_max: 0.0,
            engine: SimConfig { policy, ..SimConfig::default() },
            weights: BTreeMap::new(),
            snap_every: 64,
        })
    }

    /// The persisted form (WAL `open` record / snapshot `config` key).
    /// Watermark and defer_max travel as bit-exact hex — they feed the
    /// admission comparisons, so text rounding would break resume.
    pub fn to_json(&self) -> Json {
        let weights: BTreeMap<String, Json> = self
            .weights
            .iter()
            .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
            .collect();
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("cluster", self.cluster.to_json()),
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("watermark", Json::Str(f64_bits_hex(self.watermark))),
            ("defer_max", Json::Str(f64_bits_hex(self.defer_max))),
            ("engine", engine_json(&self.engine)),
            ("weights", Json::Obj(weights)),
        ])
    }

    pub fn from_json(j: &Json, snap_every: usize) -> Result<ServeConfig, String> {
        let ctx = |e: crate::util::json::JsonError| format!("serve config: {e}");
        if j.get("v").map_err(ctx)?.as_f64().map_err(ctx)? != 1.0 {
            return Err("serve config: unsupported version".into());
        }
        let cluster = Cluster::from_json(j.get("cluster").map_err(ctx)?)
            .map_err(|e| format!("serve config cluster: {e}"))?;
        let scheduler = j
            .get("scheduler")
            .map_err(ctx)?
            .as_str()
            .map_err(ctx)?
            .to_string();
        let policy = pinned_policy(&scheduler)?;
        let watermark = f64_from_bits_hex(j.get("watermark").map_err(ctx)?.as_str().map_err(ctx)?)
            .map_err(ctx)?;
        let defer_max = f64_from_bits_hex(j.get("defer_max").map_err(ctx)?.as_str().map_err(ctx)?)
            .map_err(ctx)?;
        let mut engine = SimConfig::default();
        engine
            .apply_json(j.get("engine").map_err(ctx)?)
            .map_err(|e| format!("serve config engine: {e}"))?;
        engine.policy = policy;
        let mut weights = BTreeMap::new();
        for (k, v) in j.get("weights").map_err(ctx)?.as_obj().map_err(ctx)? {
            let x = v.as_f64().map_err(ctx)?;
            if x.fract() != 0.0 || !x.is_finite() {
                return Err(format!("serve config weight for `{k}` must be an integer"));
            }
            weights.insert(k.clone(), x as i64);
        }
        Ok(ServeConfig {
            cluster,
            scheduler,
            watermark,
            defer_max,
            engine,
            weights,
            snap_every,
        })
    }

    fn open_config(&self) -> OpenConfig {
        OpenConfig {
            watermark: self.watermark,
            defer_max: self.defer_max,
            engine: self.engine.clone(),
        }
    }
}

/// Serialize the engine knobs in the `SimConfig::apply_json` wire
/// format (the enums expose `parse` but no label method, so the
/// spellings live here).
fn engine_json(cfg: &SimConfig) -> Json {
    Json::obj(vec![
        (
            "queue",
            Json::Str(
                match cfg.queue {
                    QueueKind::Incremental => "incremental",
                    QueueKind::FullResort => "fullresort",
                }
                .into(),
            ),
        ),
        (
            "alloc",
            Json::Str(
                match cfg.alloc {
                    AllocKind::Components => "components",
                    AllocKind::WholeSet => "wholeset",
                }
                .into(),
            ),
        ),
        (
            "horizon",
            Json::Str(
                match cfg.horizon {
                    HorizonKind::Eager => "eager",
                    HorizonKind::Anchored => "anchored",
                }
                .into(),
            ),
        ),
        ("threads", Json::Num(cfg.threads as f64)),
        ("recovery", cfg.recovery.to_json()),
    ])
}

/// A fatal service error: the server should log it, stop serving and
/// exit with `exit_code` (1 = environment/IO, 2 = deadlock,
/// 3 = event-limit — the same codes as `mxdag simulate`).
#[derive(Debug)]
pub struct Fatal {
    pub message: String,
    pub exit_code: i32,
}

impl Fatal {
    fn io(what: &str, e: std::io::Error) -> Fatal {
        Fatal { message: format!("{what}: {e}"), exit_code: 1 }
    }

    fn sim(e: crate::sim::SimError) -> Fatal {
        Fatal { message: format!("simulation failed: {e}"), exit_code: e.exit_code() }
    }
}

/// Why a submission was refused (the server maps these to HTTP codes).
#[derive(Debug)]
pub enum SubmitError {
    /// Invalid submission ⇒ 400.
    Bad(String),
    /// Admission control refused it ⇒ 429 with a Retry-After hint in
    /// *virtual* seconds (the server rescales to wall seconds).
    Busy { retry_after: f64 },
    /// The server is draining ⇒ 503.
    Draining,
    /// WAL or engine failure ⇒ 500, then shut down.
    Fatal(Fatal),
}

/// A successful submission.
#[derive(Debug)]
pub struct Submitted {
    pub seq: usize,
    /// `"admitted"`, `"deferred"` (waiting for load to drop) or
    /// `"done"` (a zero-work job can finish within its arrival era).
    pub state: &'static str,
    /// The arrival stamp actually used (monotone-floored).
    pub at: f64,
}

/// Per-job bookkeeping the engine doesn't hold: tenant, the submission
/// spec (kept until the job completes so snapshots can rebuild its
/// DAG, then dropped — bounded memory), and the stamped arrival.
#[derive(Debug)]
struct JobMeta {
    tenant: String,
    weight: i64,
    at: f64,
    spec: Option<Json>,
}

/// The WAL-backed coordinator state. One instance, one thread.
pub struct Service {
    dir: PathBuf,
    cfg: ServeConfig,
    lp: OpenLoop,
    scratch: SimScratch,
    wal: Wal,
    jobs: Vec<JobMeta>,
    last_at: f64,
    draining: bool,
    records_since_snap: usize,
}

impl Service {
    /// Initialise a fresh serve directory: create it, write the WAL
    /// `open` record carrying `cfg`. Refuses a directory that already
    /// holds serve state (use [`Service::resume`]).
    pub fn create(dir: &Path, cfg: ServeConfig) -> Result<Service, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        if wal::wal_path(dir).exists() || wal::snapshot_path(dir).exists() {
            return Err(format!(
                "{} already holds serve state — use --resume",
                dir.display()
            ));
        }
        let mut w =
            Wal::create(dir, 0).map_err(|e| format!("create WAL in {}: {e}", dir.display()))?;
        w.append("open", vec![("config", cfg.to_json())])
            .map_err(|e| format!("write WAL open record: {e}"))?;
        let lp = OpenLoop::new(&cfg.cluster, &cfg.open_config());
        Ok(Service {
            dir: dir.to_path_buf(),
            cfg,
            lp,
            scratch: SimScratch::default(),
            wal: w,
            jobs: Vec::new(),
            last_at: 0.0,
            draining: false,
            records_since_snap: 0,
        })
    }

    /// Rebuild from a serve directory: load the snapshot (if any),
    /// replay the WAL tail. Lands in bitwise-identical state to the
    /// process that wrote the log. `snap_every` is operational and
    /// comes from the caller, not the log.
    pub fn resume(dir: &Path, snap_every: usize) -> Result<Service, String> {
        let snap = wal::read_snapshot(dir)?;
        let (recs, valid_len) = wal::read_records_len(&wal::wal_path(dir))?;
        let sctx = |e: crate::util::json::JsonError| format!("snapshot: {e}");

        // config: snapshot wins; else the WAL must open with one
        let (cfg, state, mut jobs, snap_lsn) = match &snap {
            Some(s) => {
                let cfg = ServeConfig::from_json(s.get("config").map_err(sctx)?, snap_every)?;
                let lsn = s.get("lsn").map_err(sctx)?.as_f64().map_err(sctx)? as u64;
                let mut jobs = Vec::new();
                for (i, jj) in s.get("jobs").map_err(sctx)?.as_arr().map_err(sctx)?.iter().enumerate()
                {
                    let jctx = |e: crate::util::json::JsonError| format!("snapshot job {i}: {e}");
                    let tenant = jj.get("tenant").map_err(jctx)?.as_str().map_err(jctx)?.to_string();
                    let weight = jj.get("weight").map_err(jctx)?.as_f64().map_err(jctx)? as i64;
                    let at = f64_from_bits_hex(jj.get("at").map_err(jctx)?.as_str().map_err(jctx)?)
                        .map_err(jctx)?;
                    let spec = match jj.get("spec") {
                        Ok(Json::Null) | Err(_) => None,
                        Ok(v) => Some(v.clone()),
                    };
                    jobs.push(JobMeta { tenant, weight, at, spec });
                }
                (cfg, Some(s.get("state").map_err(sctx)?.clone()), jobs, Some(lsn))
            }
            None => {
                let first = recs
                    .first()
                    .ok_or_else(|| format!("{}: no snapshot and an empty WAL", dir.display()))?;
                let octx = |e: crate::util::json::JsonError| format!("WAL open record: {e}");
                if first.get("kind").map_err(octx)?.as_str().map_err(octx)? != "open" {
                    return Err("WAL does not start with an open record".into());
                }
                let cfg = ServeConfig::from_json(first.get("config").map_err(octx)?, snap_every)?;
                (cfg, None, Vec::new(), None)
            }
        };

        let ocfg = cfg.open_config();
        let mut lp = match &state {
            Some(st) => OpenLoop::restore(&cfg.cluster, &ocfg, st, &mut |idx| {
                let m = jobs
                    .get(idx)
                    .ok_or_else(|| format!("snapshot state references unknown job {idx}"))?;
                let spec = m.spec.as_ref().ok_or_else(|| {
                    format!("job {idx} is not done but its spec was dropped from the snapshot")
                })?;
                build_job(&cfg, spec, m.at, m.weight).map_err(|e| format!("job {idx}: {e}"))
            })?,
            None => OpenLoop::new(&cfg.cluster, &ocfg),
        };

        // replay the tail
        let mut scratch = SimScratch::default();
        let mut replayed = 0usize;
        let mut max_lsn = snap_lsn.unwrap_or(0);
        for (i, rec) in recs.iter().enumerate() {
            let rctx = |e: crate::util::json::JsonError| format!("WAL record {i}: {e}");
            let lsn = rec.get("lsn").map_err(rctx)?.as_f64().map_err(rctx)? as u64;
            max_lsn = max_lsn.max(lsn);
            if let Some(s0) = snap_lsn {
                if lsn <= s0 {
                    continue; // stale prefix (crash between rename and truncate)
                }
            }
            match rec.get("kind").map_err(rctx)?.as_str().map_err(rctx)? {
                "open" => {} // config already loaded above
                "job" => {
                    let seq = rec.get("seq").map_err(rctx)?.as_usize().map_err(rctx)?;
                    if seq != jobs.len() {
                        return Err(format!(
                            "WAL record {i}: job seq {seq} but {} jobs replayed",
                            jobs.len()
                        ));
                    }
                    let at =
                        f64_from_bits_hex(rec.get("at").map_err(rctx)?.as_str().map_err(rctx)?)
                            .map_err(rctx)?;
                    let tenant = rec
                        .get("tenant")
                        .map_err(rctx)?
                        .as_str()
                        .map_err(rctx)?
                        .to_string();
                    let weight = rec.get("weight").map_err(rctx)?.as_f64().map_err(rctx)? as i64;
                    let spec = rec.get("spec").map_err(rctx)?.clone();
                    let job = build_job(&cfg, &spec, at, weight)
                        .map_err(|e| format!("WAL record {i}: {e}"))?;
                    jobs.push(JobMeta { tenant, weight, at, spec: Some(spec) });
                    let got = lp.push(job);
                    debug_assert_eq!(got, seq);
                    replayed += 1;
                }
                "adv" => {
                    let to =
                        f64_from_bits_hex(rec.get("to").map_err(rctx)?.as_str().map_err(rctx)?)
                            .map_err(rctx)?;
                    lp.advance_to(to, &mut scratch)
                        .map_err(|e| format!("WAL record {i} replay: {e}"))?;
                    replayed += 1;
                }
                "drain" => {
                    lp.advance_to(f64::INFINITY, &mut scratch)
                        .map_err(|e| format!("WAL record {i} replay: {e}"))?;
                    replayed += 1;
                }
                other => return Err(format!("WAL record {i}: unknown kind `{other}`")),
            }
        }

        let wal = Wal::open_append(dir, max_lsn + 1, valid_len)
            .map_err(|e| format!("open WAL in {}: {e}", dir.display()))?;
        let last_at = jobs.iter().fold(0.0_f64, |a, m| a.max(m.at));
        Ok(Service {
            dir: dir.to_path_buf(),
            cfg,
            lp,
            scratch,
            wal,
            jobs,
            last_at,
            draining: false,
            records_since_snap: replayed,
        })
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    pub fn now(&self) -> f64 {
        self.lp.now()
    }

    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_draining(&self) -> bool {
        self.draining
    }

    /// Accept one submission at virtual time `stamp` (wall-derived by
    /// the server; this layer only floors it monotone). Write-ahead:
    /// the WAL records the push and the advance before either happens.
    pub fn submit(&mut self, body: &Json, stamp: f64) -> Result<Submitted, SubmitError> {
        if self.draining {
            return Err(SubmitError::Draining);
        }
        if !stamp.is_finite() || stamp < 0.0 {
            return Err(SubmitError::Bad(format!("bad arrival stamp {stamp}")));
        }
        let obj = body
            .as_obj()
            .map_err(|e| SubmitError::Bad(format!("submission: {e}")))?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "dag" | "scheduler" | "deadline" | "tenant") {
                return Err(SubmitError::Bad(format!(
                    "submission: unknown key `{key}` (dag|scheduler|deadline|tenant)"
                )));
            }
        }
        let tenant = match obj.get("tenant") {
            Some(v) => v
                .as_str()
                .map_err(|e| SubmitError::Bad(format!("submission tenant: {e}")))?
                .to_string(),
            None => "default".to_string(),
        };
        let weight = self.cfg.weights.get(&tenant).copied().unwrap_or(1);
        let at = stamp.max(self.last_at).max(self.lp.now());
        // validate + plan before touching the WAL: a refused submission
        // must leave no trace
        let job = build_job(&self.cfg, body, at, weight).map_err(SubmitError::Bad)?;

        let seq = self.jobs.len();
        self.wal
            .append(
                "job",
                vec![
                    ("seq", Json::Num(seq as f64)),
                    ("at", Json::Str(f64_bits_hex(at))),
                    ("tenant", Json::Str(tenant.clone())),
                    ("weight", Json::Num(weight as f64)),
                    ("spec", body.clone()),
                ],
            )
            .map_err(|e| SubmitError::Fatal(Fatal::io("WAL append", e)))?;
        self.jobs
            .push(JobMeta { tenant, weight, at, spec: Some(body.clone()) });
        self.last_at = at;
        let got = self.lp.push(job);
        debug_assert_eq!(got, seq);

        self.wal
            .append("adv", vec![("to", Json::Str(f64_bits_hex(at)))])
            .map_err(|e| SubmitError::Fatal(Fatal::io("WAL append", e)))?;
        self.lp
            .advance_to(at, &mut self.scratch)
            .map_err(|e| SubmitError::Fatal(Fatal::sim(e)))?;
        self.records_since_snap += 2;
        self.maybe_snapshot().map_err(SubmitError::Fatal)?;

        match self.lp.job_state(seq) {
            Some("live") => Ok(Submitted { seq, state: "admitted", at }),
            Some("deferred") => Ok(Submitted { seq, state: "deferred", at }),
            Some("done") => {
                let rejected = matches!(
                    self.lp.result(seq).map(|r| r.outcome),
                    Some(JobOutcome::Rejected { .. })
                );
                if rejected {
                    let est = (self.lp.max_finish() - self.lp.now()).max(1.0);
                    Err(SubmitError::Busy { retry_after: est })
                } else {
                    Ok(Submitted { seq, state: "done", at })
                }
            }
            s => Err(SubmitError::Fatal(Fatal {
                message: format!("job {seq} in impossible post-submit state {s:?}"),
                exit_code: 1,
            })),
        }
    }

    /// Advance the stream clock to `vnow` (a periodic server tick).
    /// Idle services skip the WAL record — an idle advance is a no-op
    /// by the [`OpenLoop`] contract, so logging it would only bloat
    /// the log. Returns whether an advance was issued.
    pub fn tick(&mut self, vnow: f64) -> Result<bool, Fatal> {
        if self.draining || self.lp.is_idle() {
            return Ok(false);
        }
        if !vnow.is_finite() || vnow <= self.lp.now() {
            return Ok(false);
        }
        self.wal
            .append("adv", vec![("to", Json::Str(f64_bits_hex(vnow)))])
            .map_err(|e| Fatal::io("WAL append", e))?;
        self.lp.advance_to(vnow, &mut self.scratch).map_err(Fatal::sim)?;
        self.records_since_snap += 1;
        self.maybe_snapshot()?;
        Ok(true)
    }

    /// Graceful drain: stop admitting, finish every live/deferred job
    /// (`advance_to(∞)`), flush a final snapshot. Returns the outcome
    /// report. The service still answers status reads afterwards.
    pub fn drain(&mut self) -> Result<Json, Fatal> {
        if !self.draining {
            self.draining = true;
            self.wal
                .append("drain", Vec::new())
                .map_err(|e| Fatal::io("WAL append", e))?;
            self.lp
                .advance_to(f64::INFINITY, &mut self.scratch)
                .map_err(Fatal::sim)?;
            self.snapshot()?;
        }
        Ok(self.report())
    }

    /// Status of one job, `None` for an unknown seq.
    pub fn status(&self, seq: usize) -> Option<Json> {
        let m = self.jobs.get(seq)?;
        let state = self.lp.job_state(seq)?;
        let mut pairs = vec![
            ("seq", Json::Num(seq as f64)),
            ("tenant", Json::Str(m.tenant.clone())),
            ("state", Json::Str(state.into())),
            ("arrival", Json::Num(m.at)),
        ];
        if let Some(r) = self.lp.result(seq) {
            let outcome = match r.outcome {
                JobOutcome::Completed { .. } => "completed",
                JobOutcome::Quarantined { .. } => "quarantined",
                JobOutcome::Exhausted { .. } => "exhausted",
                JobOutcome::Rejected { .. } => "rejected",
            };
            pairs.push(("outcome", Json::Str(outcome.into())));
            pairs.push((
                "admitted_at",
                r.admitted_at.map(Json::Num).unwrap_or(Json::Null),
            ));
            pairs.push(("jct", r.jct.map(Json::Num).unwrap_or(Json::Null)));
            pairs.push((
                "deadline_met",
                r.deadline_met.map(Json::Bool).unwrap_or(Json::Null),
            ));
        }
        Some(Json::obj(pairs))
    }

    /// Aggregate report: counters plus per-state and per-outcome
    /// tallies. Every submitted job appears in exactly one state —
    /// the CI resume check asserts none are lost.
    pub fn report(&self) -> Json {
        let c = self.lp.counters();
        let mut states: BTreeMap<&str, usize> = BTreeMap::new();
        let mut outcomes: BTreeMap<&str, usize> = BTreeMap::new();
        for seq in 0..self.jobs.len() {
            let s = self.lp.job_state(seq).unwrap_or("unknown");
            *states.entry(s).or_insert(0) += 1;
            if let Some(r) = self.lp.result(seq) {
                let o = match r.outcome {
                    JobOutcome::Completed { .. } => "completed",
                    JobOutcome::Quarantined { .. } => "quarantined",
                    JobOutcome::Exhausted { .. } => "exhausted",
                    JobOutcome::Rejected { .. } => "rejected",
                };
                *outcomes.entry(o).or_insert(0) += 1;
            }
        }
        let map = |m: BTreeMap<&str, usize>| {
            Json::Obj(
                m.into_iter()
                    .map(|(k, v)| (k.to_string(), Json::Num(v as f64)))
                    .collect(),
            )
        };
        Json::obj(vec![
            ("jobs", Json::Num(self.jobs.len() as f64)),
            ("now", Json::Num(self.lp.now())),
            ("draining", Json::Bool(self.draining)),
            ("eras", Json::Num(c.eras as f64)),
            ("events", Json::Num(c.events as f64)),
            ("retries", Json::Num(c.retries as f64)),
            ("lost_work", Json::Num(c.lost_work)),
            ("admitted", Json::Num(c.admitted as f64)),
            ("rejected", Json::Num(c.rejected as f64)),
            ("states", map(states)),
            ("outcomes", map(outcomes)),
        ])
    }

    /// Bitwise engine-state fingerprint (tests compare these across
    /// kill/resume).
    pub fn state_text(&self) -> String {
        self.lp.state_json().to_string()
    }

    fn maybe_snapshot(&mut self) -> Result<(), Fatal> {
        if self.records_since_snap >= self.cfg.snap_every.max(1) {
            self.snapshot()?;
        }
        Ok(())
    }

    /// Snapshot + compact: persist engine state and job metadata, then
    /// truncate the WAL. Specs of completed jobs are dropped here —
    /// restore never asks for them — keeping snapshots and memory
    /// bounded by the *live* set, not stream history.
    fn snapshot(&mut self) -> Result<(), Fatal> {
        for seq in 0..self.jobs.len() {
            if self.lp.job_state(seq) == Some("done") {
                self.jobs[seq].spec = None;
            }
        }
        let jobs: Vec<Json> = self
            .jobs
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("tenant", Json::Str(m.tenant.clone())),
                    ("weight", Json::Num(m.weight as f64)),
                    ("at", Json::Str(f64_bits_hex(m.at))),
                    ("spec", m.spec.clone().unwrap_or(Json::Null)),
                ])
            })
            .collect();
        let snap = Json::obj(vec![
            ("lsn", Json::Num((self.wal.next_lsn - 1) as f64)),
            ("config", self.cfg.to_json()),
            ("state", self.lp.state_json()),
            ("jobs", Json::Arr(jobs)),
        ]);
        wal::write_snapshot(&self.dir, &snap).map_err(|e| Fatal::io("write snapshot", e))?;
        self.wal =
            Wal::create(&self.dir, self.wal.next_lsn).map_err(|e| Fatal::io("truncate WAL", e))?;
        self.records_since_snap = 0;
        Ok(())
    }
}

/// Validate a submission body and build the engine-side job: parse the
/// DAG, check it fits the cluster, plan it with the named (or default)
/// scheduler, expand annotations. Pure — replay calls this with the
/// logged spec and gets the same DAG bit-for-bit.
fn build_job(cfg: &ServeConfig, spec: &Json, at: f64, weight: i64) -> Result<OpenJob, String> {
    let obj = spec.as_obj().map_err(|e| format!("submission: {e}"))?;
    for key in obj.keys() {
        if !matches!(key.as_str(), "dag" | "scheduler" | "deadline" | "tenant") {
            return Err(format!(
                "submission: unknown key `{key}` (dag|scheduler|deadline|tenant)"
            ));
        }
    }
    let dag_json = obj
        .get("dag")
        .ok_or_else(|| "submission: missing key `dag`".to_string())?;
    let g = MXDag::from_json(dag_json).map_err(|e| format!("submission dag: {e}"))?;
    if let Some(&h) = g.hosts().iter().max() {
        if h >= cfg.cluster.n_hosts() {
            return Err(format!(
                "submission dag references host {h} but the cluster has {} hosts",
                cfg.cluster.n_hosts()
            ));
        }
    }
    let sched_name = match obj.get("scheduler") {
        Some(v) => v.as_str().map_err(|e| format!("submission scheduler: {e}"))?,
        None => cfg.scheduler.as_str(),
    };
    if pinned_policy(sched_name)? != pinned_policy(&cfg.scheduler)? {
        return Err(format!(
            "scheduler `{sched_name}` pins a different engine policy than the server's \
             `{}` — an era runs one policy for all live jobs",
            cfg.scheduler
        ));
    }
    let sched = scheduler_by_name(sched_name)?;
    let plan = sched.plan(&g, &cfg.cluster);
    let sim = expand(&g, &plan.ann);
    let deadline = match obj.get("deadline") {
        Some(v) => {
            let d = v.as_f64().map_err(|e| format!("submission deadline: {e}"))?;
            if !d.is_finite() || d <= 0.0 {
                return Err(format!("submission deadline must be finite and > 0, got {d}"));
            }
            Some(d)
        }
        None => None,
    };
    Ok(OpenJob { at, dag: sim, deadline, weight })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("mxdag-serve-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// A two-task chain DAG in the MXDag wire format: compute on host
    /// 0, then a flow 0 → 1.
    fn chain_dag(size: f64, dst: usize) -> Json {
        let mut b = MXDag::builder();
        let c = b.compute("c", 0, size);
        let f = b.flow("f", 0, dst, size);
        b.dep(c, f);
        b.finalize().unwrap().to_json()
    }

    fn chain_spec(size: f64) -> Json {
        Json::obj(vec![("dag", chain_dag(size, 1))])
    }

    fn test_config(dir_tag: &str) -> (PathBuf, ServeConfig) {
        let dir = tmpdir(dir_tag);
        let mut cfg = ServeConfig::new(Cluster::uniform(2), "fair").unwrap();
        cfg.watermark = 10.0;
        cfg.defer_max = 0.5;
        cfg.snap_every = 4;
        cfg.weights.insert("gold".into(), 5);
        (dir, cfg)
    }

    #[test]
    fn config_roundtrips_through_json() {
        let (_, cfg) = test_config("cfg");
        let j = cfg.to_json();
        let back = ServeConfig::from_json(&j, cfg.snap_every).unwrap();
        assert_eq!(back.scheduler, "fair");
        assert_eq!(back.watermark.to_bits(), cfg.watermark.to_bits());
        assert_eq!(back.defer_max.to_bits(), cfg.defer_max.to_bits());
        assert_eq!(back.weights.get("gold"), Some(&5));
        assert_eq!(back.engine.policy, Policy::fair());
        assert_eq!(back.cluster.n_hosts(), 2);
    }

    #[test]
    fn submit_tick_drain_lifecycle() {
        let (dir, cfg) = test_config("life");
        let mut svc = Service::create(&dir, cfg).unwrap();
        let s = svc.submit(&chain_spec(1.0), 0.0).unwrap();
        assert_eq!(s.seq, 0);
        assert_eq!(s.state, "admitted");
        assert!(svc.tick(0.5).unwrap());
        // stamps are floored monotone even if the clock reads lower
        let s2 = svc.submit(&chain_spec(1.0), 0.1).unwrap();
        assert!(s2.at >= 0.5);
        let rep = svc.drain().unwrap();
        assert_eq!(rep.get("jobs").unwrap().as_f64().unwrap(), 2.0);
        let done = rep.get("states").unwrap().get("done").unwrap().as_f64().unwrap();
        assert_eq!(done, 2.0);
        let st = svc.status(0).unwrap();
        assert_eq!(st.get("outcome").unwrap().as_str().unwrap(), "completed");
        // draining refuses new work
        assert!(matches!(
            svc.submit(&chain_spec(1.0), 9.0),
            Err(SubmitError::Draining)
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_submissions_are_400_not_panics() {
        let (dir, cfg) = test_config("bad");
        let mut svc = Service::create(&dir, cfg).unwrap();
        for bad in [
            Json::Arr(vec![]),
            Json::obj(vec![("nope", Json::Null)]),
            Json::obj(vec![]),
            Json::obj(vec![("dag", Json::Str("x".into()))]),
            Json::obj(vec![
                ("dag", chain_spec(1.0).get("dag").unwrap().clone()),
                ("deadline", Json::Num(-1.0)),
            ]),
            Json::obj(vec![
                ("dag", chain_spec(1.0).get("dag").unwrap().clone()),
                ("scheduler", Json::Str("mxdag".into())), // pins priority, server is fair
            ]),
        ] {
            match svc.submit(&bad, 0.0) {
                Err(SubmitError::Bad(_)) => {}
                other => panic!("expected Bad, got {other:?}"),
            }
        }
        // a DAG referencing a host outside the 2-host cluster
        let spec = Json::obj(vec![("dag", chain_dag(1.0, 7))]);
        assert!(matches!(svc.submit(&spec, 0.0), Err(SubmitError::Bad(_))));
        // none of those left a trace
        assert_eq!(svc.n_jobs(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn overload_is_429_with_retry_hint() {
        let (dir, mut cfg) = test_config("busy");
        cfg.watermark = 0.5; // tiny drain budget
        cfg.defer_max = 0.0; // shed immediately
        let mut svc = Service::create(&dir, cfg).unwrap();
        // saturate: a long job holds the cluster past the watermark
        let s = svc.submit(&chain_spec(50.0), 0.0).unwrap();
        assert_eq!(s.state, "admitted");
        match svc.submit(&chain_spec(1.0), 0.1) {
            Err(SubmitError::Busy { retry_after }) => assert!(retry_after > 0.0),
            other => panic!("expected Busy, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_matches_uninterrupted_bitwise() {
        // one uninterrupted service vs one killed+resumed after every
        // operation batch: identical engine fingerprints
        let ops: Vec<(f64, Option<Json>)> = vec![
            (0.0, Some(chain_spec(2.0))),
            (0.3, Some(chain_spec(1.0))),
            (0.9, None), // tick
            (1.4, Some(chain_spec(0.5))),
            (2.8, None),
            (4.0, None),
        ];
        let run =
            |dir: &Path, cfg: ServeConfig, kill_resume: bool| -> String {
                let mut svc = Service::create(dir, cfg.clone()).unwrap();
                for (t, spec) in &ops {
                    match spec {
                        Some(s) => {
                            let _ = svc.submit(s, *t);
                        }
                        None => {
                            svc.tick(*t).unwrap();
                        }
                    }
                    if kill_resume {
                        drop(svc); // crash: no drain, no final snapshot
                        svc = Service::resume(dir, cfg.snap_every).unwrap();
                    }
                }
                svc.drain().unwrap();
                svc.state_text()
            };
        let (dir_a, cfg) = test_config("gold-a");
        let a = run(&dir_a, cfg.clone(), false);
        let (dir_b, _) = test_config("gold-b");
        let b = run(&dir_b, cfg, true);
        assert_eq!(a, b, "kill+resume diverged from uninterrupted run");
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn weights_come_from_server_config_not_client() {
        let (dir, cfg) = test_config("w");
        let mut svc = Service::create(&dir, cfg).unwrap();
        let mut spec = chain_spec(1.0);
        if let Json::Obj(m) = &mut spec {
            m.insert("tenant".into(), Json::Str("gold".into()));
        }
        svc.submit(&spec, 0.0).unwrap();
        let st = svc.status(0).unwrap();
        assert_eq!(st.get("tenant").unwrap().as_str().unwrap(), "gold");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
