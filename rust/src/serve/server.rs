//! The `mxdag serve` process: TCP accept loop + bounded worker pool on
//! one side, a dedicated simulation thread owning the [`Service`] on
//! the other, joined by an mpsc command channel. Thread topology:
//!
//! ```text
//! accept loop (main thread, nonblocking) ──▶ Pool workers (HTTP parse)
//!        │ queue full ⇒ 503                      │ Cmd over mpsc
//!        ▼                                       ▼
//!   SIGTERM flag                     sim thread: Service (OpenLoop+WAL)
//! ```
//!
//! The sim thread is the only owner of engine state — requests block on
//! a per-request reply channel, so the engine stays single-threaded and
//! deterministic (its own worker fan-out via `engine.threads` is
//! internal and bit-exact). Idle gaps become clock ticks:
//! `recv_timeout` expiring advances virtual time (wall seconds ×
//! `--time-scale`).
//!
//! SIGTERM/SIGINT set an atomic flag (no signal crate in this image —
//! a raw `signal(2)` binding). The drain sequence: stop accepting →
//! finish in-flight HTTP work → `Service::drain` (finish live eras,
//! flush WAL, final snapshot) → exit 0. Engine failures exit 2
//! (deadlock) / 3 (event limit), mirroring `mxdag simulate`.

use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicI32, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::Metrics;
use crate::sim::{AllocKind, Cluster, HorizonKind, QueueKind, RecoveryPolicy, SimConfig};
use crate::util::cli::Args;
use crate::util::json::Json;

use super::http::{self, Limits, Pool, Request, Response};
use super::service::{pinned_policy, ServeConfig, Service, SubmitError, Submitted};

/// Set by SIGTERM/SIGINT; polled by the accept loop.
static TERM: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    extern "C" fn on_term(_sig: i32) {
        TERM.store(true, Ordering::SeqCst);
    }
    // SIGINT = 2, SIGTERM = 15 on every unix this image targets
    unsafe {
        signal(2, on_term as usize);
        signal(15, on_term as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Commands the HTTP side sends to the sim thread.
enum Cmd {
    Submit { body: Json, reply: Sender<Result<Submitted, SubmitError>> },
    Status { seq: usize, reply: Sender<Option<Json>> },
    Report { reply: Sender<Json> },
    Drain { reply: Sender<Result<Json, String>> },
}

/// Sentinel for "sim thread still running" in the shared exit slot.
const RUNNING: i32 = i32::MIN;

/// The sim thread: sole owner of the [`Service`]. Returns the process
/// exit code; also stores it in `exit_slot` so the accept loop notices
/// a fatal engine error without joining.
fn sim_loop(
    mut svc: Service,
    rx: Receiver<Cmd>,
    tick: Duration,
    time_scale: f64,
    metrics: Arc<Metrics>,
    exit_slot: Arc<AtomicI32>,
) -> i32 {
    let t0 = Instant::now();
    let finish = |code: i32, slot: &AtomicI32| {
        slot.store(code, Ordering::SeqCst);
        code
    };
    loop {
        match rx.recv_timeout(tick) {
            Ok(Cmd::Submit { body, reply }) => {
                let vnow = t0.elapsed().as_secs_f64() * time_scale;
                let r = svc.submit(&body, vnow);
                let fatal = match &r {
                    Ok(s) => {
                        metrics.incr(&format!("submit_{}", s.state), 1);
                        None
                    }
                    Err(SubmitError::Busy { .. }) => {
                        metrics.incr("submit_rejected", 1);
                        None
                    }
                    Err(SubmitError::Bad(_)) => {
                        metrics.incr("submit_bad", 1);
                        None
                    }
                    Err(SubmitError::Draining) => None,
                    Err(SubmitError::Fatal(f)) => Some((f.message.clone(), f.exit_code)),
                };
                let _ = reply.send(r);
                if let Some((msg, code)) = fatal {
                    eprintln!("serve: fatal: {msg}");
                    return finish(code, &exit_slot);
                }
            }
            Ok(Cmd::Status { seq, reply }) => {
                let _ = reply.send(svc.status(seq));
            }
            Ok(Cmd::Report { reply }) => {
                let _ = reply.send(svc.report());
            }
            Ok(Cmd::Drain { reply }) => match svc.drain() {
                Ok(rep) => {
                    for seq in 0..svc.n_jobs() {
                        if let Some(st) = svc.status(seq) {
                            if let Ok(jct) = st.get("jct").and_then(|v| v.as_f64()) {
                                metrics.observe_secs("job_jct_vsecs", jct);
                            }
                        }
                    }
                    let _ = reply.send(Ok(rep));
                    return finish(0, &exit_slot);
                }
                Err(f) => {
                    eprintln!("serve: drain failed: {}", f.message);
                    let _ = reply.send(Err(f.message));
                    return finish(f.exit_code, &exit_slot);
                }
            },
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let vnow = t0.elapsed().as_secs_f64() * time_scale;
                if let Err(f) = svc.tick(vnow) {
                    eprintln!("serve: fatal: {}", f.message);
                    return finish(f.exit_code, &exit_slot);
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // every sender gone without an explicit drain — still
                // finish the live jobs so the WAL ends quiescent
                return match svc.drain() {
                    Ok(_) => finish(0, &exit_slot),
                    Err(f) => {
                        eprintln!("serve: drain failed: {}", f.message);
                        finish(f.exit_code, &exit_slot)
                    }
                };
            }
        }
    }
}

/// Shared request-side context for pool workers. The command sender is
/// mutex-wrapped because `mpsc::Sender` is not `Sync` on older
/// toolchains — each request clones its own handle under the lock.
struct Gateway {
    tx: std::sync::Mutex<Sender<Cmd>>,
    metrics: Arc<Metrics>,
    draining: Arc<AtomicBool>,
    time_scale: f64,
    limits: Limits,
    read_timeout: Duration,
}

fn handle_conn(gw: &Gateway, mut stream: TcpStream) {
    let started = Instant::now();
    let _ = stream.set_read_timeout(Some(gw.read_timeout));
    let _ = stream.set_write_timeout(Some(gw.read_timeout));
    let (status, what) = match http::read_request(&mut stream, &gw.limits) {
        Ok(req) => {
            let resp = route(gw, &req);
            let status = resp.status;
            let _ = resp.write(&mut stream);
            (status, format!("{} {}", req.method, req.path))
        }
        Err(e) => match e.status() {
            Some(code) => {
                let _ = Response::error(code, &e.reason()).write(&mut stream);
                (code, format!("({})", e.reason()))
            }
            None => return, // peer gone; nothing to log against
        },
    };
    gw.metrics.incr("http_requests", 1);
    gw.metrics.incr(&format!("http_{status}"), 1);
    gw.metrics.observe("http_latency", started.elapsed());
    eprintln!(
        "serve: {status} {what} {:.1}ms",
        started.elapsed().as_secs_f64() * 1e3
    );
}

/// Ask the sim thread and wait for its answer; `None` when it is gone.
fn ask<T>(tx: &Sender<Cmd>, make: impl FnOnce(Sender<T>) -> Cmd) -> Option<T> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(make(rtx)).ok()?;
    rrx.recv().ok()
}

fn route(gw: &Gateway, req: &Request) -> Response {
    let tx = gw.tx.lock().unwrap().clone();
    match (req.method.as_str(), req.path.as_str()) {
        // liveness must not block behind a long era: answered from the
        // accept-side flag, never the sim thread
        ("GET", "/healthz") => Response::json(
            200,
            Json::obj(vec![
                ("status", Json::Str("ok".into())),
                ("draining", Json::Bool(gw.draining.load(Ordering::SeqCst))),
            ]),
        ),
        ("GET", "/metrics") => Response::text(200, &gw.metrics.report()),
        ("GET", "/report") => match ask(&tx, |reply| Cmd::Report { reply }) {
            Some(rep) => Response::json(200, rep),
            None => Response::error(503, "shutting down"),
        },
        ("POST", "/jobs") => {
            let body = match Json::parse_bytes(&req.body) {
                Ok(j) => j,
                Err(e) => return Response::error(400, &format!("body: {e}")),
            };
            match ask(&tx, |reply| Cmd::Submit { body, reply }) {
                Some(Ok(s)) => Response::json(
                    202,
                    Json::obj(vec![
                        ("seq", Json::Num(s.seq as f64)),
                        ("state", Json::Str(s.state.into())),
                        ("at", Json::Num(s.at)),
                    ]),
                ),
                Some(Err(SubmitError::Bad(m))) => Response::error(400, &m),
                Some(Err(SubmitError::Busy { retry_after })) => {
                    // virtual seconds → wall seconds, rounded up
                    let wall = (retry_after / gw.time_scale).ceil().max(1.0);
                    Response::error(429, "admission control refused the job")
                        .with_header("Retry-After", &format!("{}", wall as u64))
                }
                Some(Err(SubmitError::Draining)) => Response::error(503, "draining"),
                Some(Err(SubmitError::Fatal(f))) => Response::error(500, &f.message),
                None => Response::error(503, "shutting down"),
            }
        }
        ("GET", p) if p.starts_with("/jobs/") => match p["/jobs/".len()..].parse::<usize>() {
            Ok(seq) => match ask(&tx, |reply| Cmd::Status { seq, reply }) {
                Some(Some(j)) => Response::json(200, j),
                Some(None) => Response::error(404, &format!("no job {seq}")),
                None => Response::error(503, "shutting down"),
            },
            Err(_) => Response::error(404, "job id must be an integer"),
        },
        (_, "/healthz" | "/metrics" | "/report" | "/jobs") => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, "unknown route"),
    }
}

/// Apply `--queue/--alloc/--horizon/--threads/--recovery` to an engine
/// config (the serve-side mirror of `mxdag simulate`'s flags).
fn engine_from_args(args: &Args, cfg: &mut SimConfig) -> Result<(), String> {
    if let Some(v) = args.get("queue") {
        cfg.queue = QueueKind::parse(v).map_err(|e| format!("--queue: {e}"))?;
    }
    if let Some(v) = args.get("alloc") {
        cfg.alloc = AllocKind::parse(v).map_err(|e| format!("--alloc: {e}"))?;
    }
    if let Some(v) = args.get("horizon") {
        cfg.horizon = HorizonKind::parse(v).map_err(|e| format!("--horizon: {e}"))?;
    }
    if let Some(v) = args.get("threads") {
        match v.parse::<usize>() {
            Ok(t) if t >= 1 => cfg.threads = t,
            _ => return Err(format!("--threads: expected an integer >= 1, got {v:?}")),
        }
    }
    if let Some(v) = args.get("recovery") {
        cfg.recovery = RecoveryPolicy::parse(v).map_err(|e| format!("--recovery: {e}"))?;
    }
    Ok(())
}

/// `--weights gold=5,bronze=1`
fn parse_weights(s: &str) -> Result<std::collections::BTreeMap<String, i64>, String> {
    let mut m = std::collections::BTreeMap::new();
    for part in s.split(',').filter(|p| !p.is_empty()) {
        let (k, v) = part
            .split_once('=')
            .ok_or_else(|| format!("--weights: expected NAME=INT, got `{part}`"))?;
        let w: i64 = v
            .trim()
            .parse()
            .map_err(|_| format!("--weights: bad integer `{v}`"))?;
        if w < 1 {
            return Err(format!("--weights: weight for `{k}` must be >= 1"));
        }
        m.insert(k.trim().to_string(), w);
    }
    Ok(m)
}

/// Build a fresh [`ServeConfig`] from CLI flags.
fn config_from_args(args: &Args) -> Result<ServeConfig, String> {
    let cluster = match args.get("cluster") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
            let j = Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
            Cluster::from_json(&j).map_err(|e| format!("--cluster: {e}"))?
        }
        None => Cluster::uniform(args.usize_or("hosts", 4).max(1)),
    };
    let scheduler = args.get_or("scheduler", "mxdag");
    pinned_policy(&scheduler)?;
    let mut cfg = ServeConfig::new(cluster, &scheduler)?;
    let watermark = args.f64_or("watermark", f64::INFINITY);
    if watermark.is_nan() || watermark < 0.0 {
        return Err(format!("--watermark: expected a number >= 0, got {watermark}"));
    }
    cfg.watermark = watermark;
    let defer_max = args.f64_or("defer-max", 0.0);
    if !defer_max.is_finite() || defer_max < 0.0 {
        return Err(format!("--defer-max: expected a finite number >= 0, got {defer_max}"));
    }
    cfg.defer_max = defer_max;
    engine_from_args(args, &mut cfg.engine)?;
    if let Some(w) = args.get("weights") {
        cfg.weights = parse_weights(w)?;
    }
    cfg.snap_every = args.usize_or("snap-every", 64).max(1);
    Ok(cfg)
}

/// Entry point for `mxdag serve`. Returns the process exit code:
/// 0 = clean drain, 1 = config/environment error, 2 = engine deadlock,
/// 3 = engine event-limit.
pub fn run(args: &Args) -> i32 {
    let snap_every = args.usize_or("snap-every", 64).max(1);
    let (dir, resume) = match (args.get("resume"), args.get("dir")) {
        (Some(d), _) => (d.to_string(), true),
        (None, Some(d)) => (d.to_string(), false),
        (None, None) => {
            eprintln!("serve: --dir DIR (fresh) or --resume DIR required");
            return 1;
        }
    };
    let svc = if resume {
        match Service::resume(Path::new(&dir), snap_every) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve: resume {dir}: {e}");
                return 1;
            }
        }
    } else {
        let cfg = match config_from_args(args) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("serve: {e}");
                return 1;
            }
        };
        match Service::create(Path::new(&dir), cfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("serve: {e}");
                return 1;
            }
        }
    };
    // --check: report the recovered state and exit without serving —
    // the CI resume check asserts zero lost jobs this way
    if args.flag("check") {
        println!("{}", svc.report());
        return 0;
    }

    let time_scale = args.f64_or("time-scale", 1.0);
    if !time_scale.is_finite() || time_scale <= 0.0 {
        eprintln!("serve: --time-scale must be finite and > 0");
        return 1;
    }
    let tick = Duration::from_millis(args.usize_or("tick-ms", 50).max(1) as u64);
    let limits = Limits {
        max_body: args.usize_or("max-body", 1024 * 1024).max(1),
        ..Limits::default()
    };
    let read_timeout =
        Duration::from_millis(args.usize_or("read-timeout-ms", 5000).max(1) as u64);

    let host = args.get_or("host", "127.0.0.1");
    let port = args.usize_or("port", 0) as u16;
    let listener = match TcpListener::bind((host.as_str(), port)) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("serve: bind {host}:{port}: {e}");
            return 1;
        }
    };
    let addr = match listener.local_addr() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("serve: local_addr: {e}");
            return 1;
        }
    };
    if let Some(path) = args.get("addr-file") {
        if let Err(e) = std::fs::write(path, format!("{addr}\n")) {
            eprintln!("serve: write {path}: {e}");
            return 1;
        }
    }
    if let Err(e) = listener.set_nonblocking(true) {
        eprintln!("serve: set_nonblocking: {e}");
        return 1;
    }
    install_signal_handlers();
    eprintln!(
        "serve: listening on {addr} dir={dir} scheduler={} jobs={} (resume={resume})",
        svc.config().scheduler,
        svc.n_jobs()
    );

    let metrics = Arc::new(Metrics::new());
    let draining = Arc::new(AtomicBool::new(false));
    let exit_slot = Arc::new(AtomicI32::new(RUNNING));
    let (tx, rx) = mpsc::channel::<Cmd>();
    let sim = {
        let metrics = Arc::clone(&metrics);
        let exit_slot = Arc::clone(&exit_slot);
        std::thread::Builder::new()
            .name("mxdag-sim".into())
            .spawn(move || sim_loop(svc, rx, tick, time_scale, metrics, exit_slot))
            .expect("spawn sim thread")
    };
    let gw = Arc::new(Gateway {
        tx: tx.clone(),
        metrics: Arc::clone(&metrics),
        draining: Arc::clone(&draining),
        time_scale,
        limits,
        read_timeout,
    });
    let pool = {
        let gw = Arc::clone(&gw);
        Pool::new(
            args.usize_or("workers", 4).max(1),
            args.usize_or("queue-cap", 64).max(1),
            move |s| handle_conn(&gw, s),
        )
    };

    // accept loop: poll the TERM flag and the sim thread's exit slot
    loop {
        if TERM.load(Ordering::SeqCst) {
            eprintln!("serve: signal received, draining");
            break;
        }
        if exit_slot.load(Ordering::SeqCst) != RUNNING {
            eprintln!("serve: sim thread stopped, shutting down");
            break;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                if let Err(mut refused) = pool.submit(stream) {
                    // bounded backpressure: answer 503 instead of queueing
                    let _ = refused.set_write_timeout(Some(read_timeout));
                    let _ = Response::error(503, "request queue full")
                        .with_header("Retry-After", "1")
                        .write(&mut refused);
                    metrics.incr("http_503_shed", 1);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                eprintln!("serve: accept: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }

    // graceful drain: stop accepting → finish in-flight HTTP work →
    // finish live eras + flush WAL → exit
    drop(listener);
    draining.store(true, Ordering::SeqCst);
    drop(gw); // release the pool-side tx clone template
    pool.close();
    let (rtx, rrx) = mpsc::channel();
    if tx.send(Cmd::Drain { reply: rtx }).is_ok() {
        match rrx.recv() {
            Ok(Ok(rep)) => eprintln!("serve: drained: {rep}"),
            Ok(Err(e)) => eprintln!("serve: drain error: {e}"),
            Err(_) => {}
        }
    }
    drop(tx);
    let code = sim.join().unwrap_or(1);
    eprintln!("serve: exit {code}");
    code
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_parse() {
        let w = parse_weights("gold=5, bronze=1").unwrap();
        assert_eq!(w.get("gold"), Some(&5));
        assert_eq!(w.get("bronze"), Some(&1));
        assert!(parse_weights("gold").is_err());
        assert!(parse_weights("gold=0").is_err());
        assert!(parse_weights("gold=x").is_err());
        assert!(parse_weights("").unwrap().is_empty());
    }

    #[test]
    fn engine_flags_apply() {
        let args = Args::parse(
            ["serve", "--queue", "fullresort", "--threads", "2", "--recovery", "retry:2:0.5"]
                .iter()
                .map(|s| s.to_string()),
        );
        let mut cfg = SimConfig::default();
        engine_from_args(&args, &mut cfg).unwrap();
        assert!(matches!(cfg.queue, QueueKind::FullResort));
        assert_eq!(cfg.threads, 2);
        assert!(matches!(cfg.recovery, RecoveryPolicy::Retry { max_attempts: 2, .. }));
        let bad = Args::parse(["serve", "--queue", "nope"].iter().map(|s| s.to_string()));
        assert!(engine_from_args(&bad, &mut cfg).is_err());
    }

    #[test]
    fn config_from_args_validates() {
        let ok = Args::parse(
            ["serve", "--hosts", "3", "--scheduler", "fair", "--watermark", "5", "--weights", "a=2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = config_from_args(&ok).unwrap();
        assert_eq!(cfg.cluster.n_hosts(), 3);
        assert_eq!(cfg.scheduler, "fair");
        assert_eq!(cfg.watermark, 5.0);
        assert_eq!(cfg.weights.get("a"), Some(&2));
        let bad = Args::parse(
            ["serve", "--scheduler", "nope"].iter().map(|s| s.to_string()),
        );
        assert!(config_from_args(&bad).is_err());
    }
}
