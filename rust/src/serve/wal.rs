//! Write-ahead log + snapshot for crash-safe `mxdag serve`.
//!
//! The determinism contract (see `sim/openloop.rs`): an [`OpenLoop`]'s
//! outcomes are a pure function of its *call sequence* — the pushes and
//! the advance targets — because era stops are not bitwise-neutral
//! (splitting an era rounds `remaining`/gate rebasing differently). So
//! the WAL records exactly that call sequence:
//!
//! ```text
//! {"lsn":0,"kind":"open","config":{...}}        serve config, once
//! {"lsn":1,"kind":"job","seq":0,"at":"4008...","tenant":"a","weight":3,"spec":{...}}
//! {"lsn":2,"kind":"adv","to":"4008..."}
//! {"lsn":3,"kind":"drain"}
//! ```
//!
//! One JSON object per line, strictly increasing `lsn`, arrival stamps
//! and advance targets as bit-exact `f64` hex (`util::json::f64_bits_hex`
//! — `Json::Num` cannot round-trip every bit pattern through text).
//! Records are appended *before* the state change they describe
//! (write-ahead) and fsynced, so replaying the log re-issues the exact
//! same call sequence and lands in bitwise-identical state.
//!
//! Compaction: every `snap_every` records the service writes
//! `snapshot.json` (`{"lsn":N,"config":...,"state":<OpenLoop::state_json>,
//! "jobs":[...]}`) via tmp-file + atomic rename, then truncates
//! `wal.log`. `lsn` keeps increasing across truncations; replay skips
//! records with `lsn <= snapshot.lsn`, so a crash between the rename
//! and the truncate is harmless (the stale WAL prefix is ignored).
//!
//! Torn-tail tolerance: a crash mid-append can leave a partial final
//! line. [`read_records`] drops an unparsable *final* line (its record
//! was never acknowledged — the write-ahead ordering means the state
//! change it described never happened) but treats corruption anywhere
//! else as fatal.

use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

pub fn wal_path(dir: &Path) -> PathBuf {
    dir.join("wal.log")
}

pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join("snapshot.json")
}

/// Append handle for `wal.log`. Every append writes one line and
/// fsyncs before returning — an acknowledged record survives a crash.
pub struct Wal {
    file: File,
    path: PathBuf,
    /// LSN the next append will carry (strictly increasing for the
    /// lifetime of the serve directory, across compactions).
    pub next_lsn: u64,
}

impl Wal {
    /// Create (or truncate) `wal.log`; `next_lsn` continues the
    /// directory-lifetime sequence (0 for a fresh directory).
    pub fn create(dir: &Path, next_lsn: u64) -> std::io::Result<Wal> {
        let path = wal_path(dir);
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(&path)?;
        Ok(Wal { file, path, next_lsn })
    }

    /// Open `wal.log` for appending after replay decided `next_lsn`.
    /// `valid_len` is the byte length of the valid record prefix (from
    /// [`read_records_len`]); anything past it is a torn tail from a
    /// crash mid-append and is truncated away here — appending *after*
    /// torn bytes would glue the next record onto the partial line and
    /// turn a tolerable torn tail into fatal mid-file corruption on the
    /// following resume.
    pub fn open_append(dir: &Path, next_lsn: u64, valid_len: u64) -> std::io::Result<Wal> {
        let path = wal_path(dir);
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        if file.metadata()?.len() > valid_len {
            file.set_len(valid_len)?;
            file.sync_data()?;
        }
        Ok(Wal { file, path, next_lsn })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record; `fields` is everything but `lsn`/`kind`.
    /// Returns the record's LSN.
    pub fn append(&mut self, kind: &str, fields: Vec<(&str, Json)>) -> std::io::Result<u64> {
        let lsn = self.next_lsn;
        let mut pairs = vec![
            ("lsn", Json::Num(lsn as f64)),
            ("kind", Json::Str(kind.into())),
        ];
        pairs.extend(fields);
        let mut line = Json::obj(pairs).to_string();
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        self.file.sync_data()?;
        self.next_lsn = lsn + 1;
        Ok(lsn)
    }
}

/// Read every record from `wal.log`, tolerating (and dropping) a torn
/// final line. See [`read_records_len`].
pub fn read_records(path: &Path) -> Result<Vec<Json>, String> {
    read_records_len(path).map(|(recs, _)| recs)
}

/// Read every record from `wal.log`, tolerating (and dropping) a torn
/// final line. Returns the records in order plus the byte length of
/// the valid prefix (what [`Wal::open_append`] truncates to); validates
/// that `lsn`s are strictly increasing. A missing file reads as empty.
pub fn read_records_len(path: &Path) -> Result<(Vec<Json>, u64), String> {
    let mut bytes = Vec::new();
    match File::open(path) {
        Ok(mut f) => {
            f.read_to_end(&mut bytes)
                .map_err(|e| format!("read {}: {e}", path.display()))?;
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok((Vec::new(), 0)),
        Err(e) => return Err(format!("open {}: {e}", path.display())),
    }
    let mut out = Vec::new();
    let mut last_lsn: Option<u64> = None;
    let mut valid_len = bytes.len() as u64;
    let lines: Vec<&[u8]> = bytes.split(|&b| b == b'\n').collect();
    let mut offset = 0u64; // byte offset of the current line's start
    for (i, line) in lines.iter().enumerate() {
        if line.is_empty() {
            offset += 1; // the newline that produced this empty split
            continue;
        }
        // is any non-empty line after this one? (trailing "" from the
        // final newline doesn't count)
        let is_last = lines[i + 1..].iter().all(|l| l.is_empty());
        let rec = match Json::parse_bytes(line).and_then(|j| {
            let lsn = j.get("lsn")?.as_f64()? as u64;
            let _ = j.get("kind")?.as_str()?;
            Ok((lsn, j))
        }) {
            Ok(v) => v,
            Err(e) if is_last => {
                // torn tail: the append never acknowledged, the state
                // change never happened — drop it
                eprintln!(
                    "serve: dropping torn WAL tail ({} bytes, line {}): {e}",
                    line.len(),
                    i + 1
                );
                valid_len = offset;
                break;
            }
            Err(e) => {
                return Err(format!(
                    "corrupt WAL {} line {}: {e}",
                    path.display(),
                    i + 1
                ));
            }
        };
        let (lsn, j) = rec;
        if let Some(prev) = last_lsn {
            if lsn <= prev {
                return Err(format!(
                    "corrupt WAL {}: lsn {lsn} after {prev} (line {})",
                    path.display(),
                    i + 1
                ));
            }
        }
        last_lsn = Some(lsn);
        out.push(j);
        offset += line.len() as u64 + 1;
    }
    Ok((out, valid_len))
}

/// Write `snapshot.json` atomically: tmp file + fsync + rename.
pub fn write_snapshot(dir: &Path, snapshot: &Json) -> std::io::Result<()> {
    let tmp = dir.join("snapshot.json.tmp");
    {
        let mut f = File::create(&tmp)?;
        f.write_all(snapshot.to_string().as_bytes())?;
        f.write_all(b"\n")?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, snapshot_path(dir))
}

/// Read `snapshot.json` if present.
pub fn read_snapshot(dir: &Path) -> Result<Option<Json>, String> {
    let path = snapshot_path(dir);
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(format!("read {}: {e}", path.display())),
    };
    Json::parse(&text)
        .map(Some)
        .map_err(|e| format!("parse {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "mxdag-wal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn append_read_roundtrip() {
        let dir = tmpdir("rt");
        let mut w = Wal::create(&dir, 0).unwrap();
        assert_eq!(w.append("open", vec![("config", Json::Null)]).unwrap(), 0);
        assert_eq!(
            w.append("adv", vec![("to", Json::Str("3ff0000000000000".into()))])
                .unwrap(),
            1
        );
        let recs = read_records(&wal_path(&dir)).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].get("kind").unwrap().as_str().unwrap(), "open");
        assert_eq!(recs[1].get("lsn").unwrap().as_f64().unwrap(), 1.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_dropped_midfile_corruption_is_fatal() {
        let dir = tmpdir("torn");
        let mut w = Wal::create(&dir, 5).unwrap();
        w.append("adv", vec![("to", Json::Str("0".repeat(16)))]).unwrap();
        // simulate a crash mid-append: partial final line, no newline
        let mut f = OpenOptions::new()
            .append(true)
            .open(wal_path(&dir))
            .unwrap();
        f.write_all(b"{\"lsn\":6,\"kind\":\"adv\",\"to\":\"40").unwrap();
        drop(f);
        let (recs, valid_len) = read_records_len(&wal_path(&dir)).unwrap();
        assert_eq!(recs.len(), 1, "torn tail dropped");

        // reopening for append must truncate the torn bytes — else the
        // next record would glue onto the partial line and a later
        // resume would see fatal mid-file corruption
        let mut w = Wal::open_append(&dir, 6, valid_len).unwrap();
        w.append("adv", vec![("to", Json::Str("1".repeat(16)))]).unwrap();
        let (recs, len2) = read_records_len(&wal_path(&dir)).unwrap();
        assert_eq!(recs.len(), 2, "clean append after truncation");
        assert_eq!(recs[1].get("lsn").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(
            len2,
            std::fs::metadata(wal_path(&dir)).unwrap().len(),
            "no torn bytes left"
        );

        // corruption in the *middle* must not be silently skipped
        std::fs::write(
            wal_path(&dir),
            b"{\"lsn\":1,\"kind\":\"adv\"}\ngarbage\n{\"lsn\":2,\"kind\":\"adv\"}\n",
        )
        .unwrap();
        assert!(read_records(&wal_path(&dir)).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lsn_regression_is_fatal_and_missing_file_reads_empty() {
        let dir = tmpdir("lsn");
        std::fs::write(
            wal_path(&dir),
            b"{\"lsn\":4,\"kind\":\"adv\"}\n{\"lsn\":4,\"kind\":\"adv\"}\n",
        )
        .unwrap();
        assert!(read_records(&wal_path(&dir)).is_err());
        assert!(read_records(&dir.join("nope.log")).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_roundtrip() {
        let dir = tmpdir("snap");
        assert!(read_snapshot(&dir).unwrap().is_none());
        let snap = Json::obj(vec![("lsn", Json::Num(7.0)), ("state", Json::Null)]);
        write_snapshot(&dir, &snap).unwrap();
        let got = read_snapshot(&dir).unwrap().unwrap();
        assert_eq!(got.get("lsn").unwrap().as_f64().unwrap(), 7.0);
        assert!(!dir.join("snapshot.json.tmp").exists(), "tmp renamed away");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
