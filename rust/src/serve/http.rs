//! Minimal HTTP/1.1 substrate for `mxdag serve` (no HTTP crate in this
//! image). Deliberately a *subset*: every connection is
//! `Connection: close`, request bodies require `Content-Length`
//! (chunked transfer encoding is answered with `501`), and hard caps
//! bound every read — header bytes (`431`), body bytes (`413`) and
//! wall time per read (`408` via socket timeouts set by the caller).
//! The parser never panics on hostile input: every malformed shape maps
//! to a typed [`HttpError`] carrying the status code the caller should
//! answer with.
//!
//! The listener side lives in `serve/server.rs`; this module only knows
//! how to read one [`Request`] from a stream, write one [`Response`],
//! and fan accepted connections across a bounded worker [`Pool`]
//! (queue full ⇒ the caller answers `503` instead of accepting
//! unbounded memory).

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::json::Json;

/// Read-side limits. The socket timeouts themselves are set by the
/// accept loop (`TcpStream::set_read_timeout`); this struct carries the
/// byte caps the parser enforces.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    /// Max bytes of request line + headers (before the blank line).
    pub max_head: usize,
    /// Max bytes of request body (`Content-Length` above this ⇒ 413).
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_head: 8 * 1024, max_body: 1024 * 1024 }
    }
}

/// One parsed request. Header names are lowercased; values are
/// trimmed. The target is split at the first `?` into `path` and
/// `query`.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub query: Option<String>,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Why a request could not be read. [`HttpError::status`] maps each
/// variant to the response code; `Closed`/`Io` mean the peer is gone
/// and no response can be written.
#[derive(Debug)]
pub enum HttpError {
    /// A socket read timed out (slow-loris) ⇒ 408.
    Timeout,
    /// `Content-Length` exceeds the body cap ⇒ 413.
    TooLarge,
    /// Request line + headers exceed the head cap ⇒ 431.
    HeadTooLarge,
    /// Syntactically invalid request ⇒ 400.
    Malformed(String),
    /// A body-bearing method without `Content-Length` ⇒ 411.
    LengthRequired,
    /// A feature this subset does not speak (chunked bodies) ⇒ 501.
    Unsupported(String),
    /// Peer closed before a full request arrived; nothing to answer.
    Closed,
    /// Transport error mid-read; nothing to answer.
    Io(String),
}

impl HttpError {
    /// Response status for this error, `None` when the peer is gone.
    pub fn status(&self) -> Option<u16> {
        match self {
            HttpError::Timeout => Some(408),
            HttpError::TooLarge => Some(413),
            HttpError::HeadTooLarge => Some(431),
            HttpError::Malformed(_) => Some(400),
            HttpError::LengthRequired => Some(411),
            HttpError::Unsupported(_) => Some(501),
            HttpError::Closed | HttpError::Io(_) => None,
        }
    }

    pub fn reason(&self) -> String {
        match self {
            HttpError::Timeout => "read timed out".into(),
            HttpError::TooLarge => "request body too large".into(),
            HttpError::HeadTooLarge => "request header too large".into(),
            HttpError::Malformed(m) => format!("malformed request: {m}"),
            HttpError::LengthRequired => "Content-Length required".into(),
            HttpError::Unsupported(m) => format!("unsupported: {m}"),
            HttpError::Closed => "peer closed".into(),
            HttpError::Io(m) => format!("io: {m}"),
        }
    }
}

fn io_err(e: io::Error) -> HttpError {
    match e.kind() {
        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => HttpError::Timeout,
        io::ErrorKind::UnexpectedEof
        | io::ErrorKind::ConnectionReset
        | io::ErrorKind::ConnectionAborted
        | io::ErrorKind::BrokenPipe => HttpError::Closed,
        _ => HttpError::Io(e.to_string()),
    }
}

/// Read one request from `stream`. The caller must have set read/write
/// timeouts on the stream; a timeout surfaces as [`HttpError::Timeout`].
/// Answers `Expect: 100-continue` inline (curl sends it for bodies over
/// ~1 KiB) so clients do not stall waiting for the interim response.
pub fn read_request(stream: &mut TcpStream, limits: &Limits) -> Result<Request, HttpError> {
    // --- head: read until the blank line, capped at max_head ---
    let mut head: Vec<u8> = Vec::with_capacity(512);
    let mut rest: Vec<u8> = Vec::new(); // body bytes read past the head
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find_blank_line(&head) {
            break pos;
        }
        if head.len() >= limits.max_head {
            return Err(HttpError::HeadTooLarge);
        }
        let n = stream.read(&mut chunk).map_err(io_err)?;
        if n == 0 {
            return if head.is_empty() {
                Err(HttpError::Closed)
            } else {
                Err(HttpError::Malformed("eof inside request head".into()))
            };
        }
        head.extend_from_slice(&chunk[..n]);
    };
    // bytes after the blank line belong to the body
    rest.extend_from_slice(&head[head_end + 4..]);
    head.truncate(head_end);
    if head.len() > limits.max_head {
        return Err(HttpError::HeadTooLarge);
    }

    let head_str = std::str::from_utf8(&head)
        .map_err(|_| HttpError::Malformed("non-UTF-8 request head".into()))?;
    let mut lines = head_str.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| HttpError::Malformed("empty request line".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| HttpError::Malformed("missing request target".into()))?;
    let version = parts
        .next()
        .ok_or_else(|| HttpError::Malformed("missing HTTP version".into()))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Malformed(format!("bad version `{version}`")));
    }
    if !target.starts_with('/') {
        return Err(HttpError::Malformed(format!("bad target `{target}`")));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once(':')
            .ok_or_else(|| HttpError::Malformed(format!("bad header line `{line}`")))?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }
    let mut req = Request { method, path, query, headers, body: Vec::new() };

    // --- body ---
    if let Some(te) = req.header("transfer-encoding") {
        if !te.eq_ignore_ascii_case("identity") {
            return Err(HttpError::Unsupported(format!("transfer-encoding: {te}")));
        }
    }
    let wants_body = matches!(req.method.as_str(), "POST" | "PUT" | "PATCH");
    let len = match req.header("content-length") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::Malformed(format!("bad Content-Length `{v}`")))?,
        None if wants_body => return Err(HttpError::LengthRequired),
        None => 0,
    };
    if len > limits.max_body {
        return Err(HttpError::TooLarge);
    }
    if len > 0 {
        if req
            .header("expect")
            .is_some_and(|v| v.eq_ignore_ascii_case("100-continue"))
            && rest.is_empty()
        {
            stream
                .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                .map_err(io_err)?;
        }
        let mut body = rest;
        body.truncate(len.min(body.len()));
        while body.len() < len {
            let want = (len - body.len()).min(chunk.len());
            let n = stream.read(&mut chunk[..want]).map_err(io_err)?;
            if n == 0 {
                return Err(HttpError::Malformed("eof inside request body".into()));
            }
            body.extend_from_slice(&chunk[..n]);
        }
        req.body = body;
    }
    Ok(req)
}

fn find_blank_line(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// One response, always `Connection: close`.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers, e.g. `("Retry-After", "3")`.
    pub extra: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, j: Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: j.to_string().into_bytes(),
            extra: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.as_bytes().to_vec(),
            extra: Vec::new(),
        }
    }

    /// A JSON error envelope: `{"error": reason}`.
    pub fn error(status: u16, reason: &str) -> Response {
        Response::json(status, Json::obj(vec![("error", Json::Str(reason.into()))]))
    }

    pub fn with_header(mut self, name: &str, value: &str) -> Response {
        self.extra.push((name.to_string(), value.to_string()));
        self
    }

    pub fn write(&self, stream: &mut TcpStream) -> io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            status_reason(self.status),
            self.content_type,
            self.body.len()
        );
        for (k, v) in &self.extra {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Bounded worker pool for accepted connections. `submit` refuses when
/// the queue is at capacity (the accept loop then answers `503` and
/// drops the connection) — backpressure instead of unbounded memory.
/// `close` drains the queue, lets in-flight handlers finish, and joins
/// every worker — the graceful-drain half of SIGTERM handling.
pub struct Pool {
    inner: Arc<PoolInner>,
    workers: Vec<JoinHandle<()>>,
}

struct PoolInner {
    q: Mutex<PoolQueue>,
    cv: Condvar,
    cap: usize,
}

struct PoolQueue {
    items: VecDeque<TcpStream>,
    closed: bool,
}

impl Pool {
    pub fn new<F>(workers: usize, cap: usize, handler: F) -> Pool
    where
        F: Fn(TcpStream) + Send + Sync + 'static,
    {
        let inner = Arc::new(PoolInner {
            q: Mutex::new(PoolQueue { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
        });
        let handler = Arc::new(handler);
        let workers = (0..workers.max(1))
            .map(|_| {
                let inner = Arc::clone(&inner);
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || loop {
                    let next = {
                        let mut q = inner.q.lock().unwrap();
                        loop {
                            if let Some(s) = q.items.pop_front() {
                                break Some(s);
                            }
                            if q.closed {
                                break None;
                            }
                            q = inner.cv.wait(q).unwrap();
                        }
                    };
                    match next {
                        Some(stream) => handler(stream),
                        None => return,
                    }
                })
            })
            .collect();
        Pool { inner, workers }
    }

    /// Hand a connection to the pool; `Err` gives the stream back when
    /// the queue is full or the pool is closed (caller answers 503).
    pub fn submit(&self, stream: TcpStream) -> Result<(), TcpStream> {
        let mut q = self.inner.q.lock().unwrap();
        if q.closed || q.items.len() >= self.inner.cap {
            return Err(stream);
        }
        q.items.push_back(stream);
        self.inner.cv.notify_one();
        Ok(())
    }

    /// Queue depth right now (for /healthz reporting).
    pub fn depth(&self) -> usize {
        self.inner.q.lock().unwrap().items.len()
    }

    /// Stop accepting, finish queued + in-flight work, join workers.
    pub fn close(mut self) {
        self.inner.q.lock().unwrap().closed = true;
        self.inner.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};
    use std::time::Duration;

    /// Write `raw` into a socket pair and parse it off the other end.
    fn roundtrip(raw: &[u8], limits: &Limits) -> Result<Request, HttpError> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        server
            .set_read_timeout(Some(Duration::from_millis(500)))
            .unwrap();
        client.write_all(raw).unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        read_request(&mut server, limits)
    }

    #[test]
    fn parses_a_post_with_body() {
        let raw = b"POST /jobs?x=1 HTTP/1.1\r\nHost: h\r\nContent-Length: 5\r\n\r\nhello";
        let r = roundtrip(raw, &Limits::default()).unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/jobs");
        assert_eq!(r.query.as_deref(), Some("x=1"));
        assert_eq!(r.header("host"), Some("h"));
        assert_eq!(r.body, b"hello");
    }

    #[test]
    fn get_without_length_is_fine() {
        let raw = b"GET /healthz HTTP/1.1\r\nHost: h\r\n\r\n";
        let r = roundtrip(raw, &Limits::default()).unwrap();
        assert_eq!(r.method, "GET");
        assert!(r.body.is_empty());
    }

    #[test]
    fn post_without_length_is_411() {
        let raw = b"POST /jobs HTTP/1.1\r\nHost: h\r\n\r\n";
        let e = roundtrip(raw, &Limits::default()).unwrap_err();
        assert_eq!(e.status(), Some(411));
    }

    #[test]
    fn oversized_body_is_413() {
        let raw = b"POST /jobs HTTP/1.1\r\nContent-Length: 99\r\n\r\n";
        let limits = Limits { max_body: 10, ..Limits::default() };
        let e = roundtrip(raw, &limits).unwrap_err();
        assert_eq!(e.status(), Some(413));
    }

    #[test]
    fn oversized_head_is_431() {
        let mut raw = b"GET / HTTP/1.1\r\n".to_vec();
        raw.extend_from_slice(format!("X-Pad: {}\r\n\r\n", "a".repeat(9000)).as_bytes());
        let e = roundtrip(&raw, &Limits::default()).unwrap_err();
        assert_eq!(e.status(), Some(431));
    }

    #[test]
    fn chunked_is_501_and_garbage_is_400() {
        let raw = b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        let e = roundtrip(raw, &Limits::default()).unwrap_err();
        assert_eq!(e.status(), Some(501));
        let e = roundtrip(b"nonsense\r\n\r\n", &Limits::default()).unwrap_err();
        assert_eq!(e.status(), Some(400), "{e:?}");
        let e = roundtrip(b"\x00\xff\xfe garbage \r\n\r\n", &Limits::default()).unwrap_err();
        assert_eq!(e.status(), Some(400));
    }

    #[test]
    fn slow_loris_times_out_as_408() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        server
            .set_read_timeout(Some(Duration::from_millis(50)))
            .unwrap();
        // send half a request line, then stall
        client.write_all(b"GET /slow HTT").unwrap();
        let e = read_request(&mut server, &Limits::default()).unwrap_err();
        assert_eq!(e.status(), Some(408));
    }

    #[test]
    fn response_writes_status_line_and_headers() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server, _) = listener.accept().unwrap();
        Response::json(202, Json::obj(vec![("ok", Json::Bool(true))]))
            .with_header("Retry-After", "3")
            .write(&mut server)
            .unwrap();
        drop(server);
        let mut got = String::new();
        client.read_to_string(&mut got).unwrap();
        assert!(got.starts_with("HTTP/1.1 202 Accepted\r\n"), "{got}");
        assert!(got.contains("Retry-After: 3\r\n"));
        assert!(got.contains("Connection: close\r\n"));
        assert!(got.ends_with("{\"ok\":true}"));
    }

    #[test]
    fn pool_backpressure_and_drain() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let handled = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&handled);
        let pool = Pool::new(2, 4, move |s| {
            h.fetch_add(1, Ordering::SeqCst);
            drop(s);
        });
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut n_ok = 0;
        let mut clients = Vec::new();
        for _ in 0..16 {
            clients.push(TcpStream::connect(addr).unwrap());
            let (s, _) = listener.accept().unwrap();
            if pool.submit(s).is_ok() {
                n_ok += 1;
            }
        }
        // cap 4 + whatever the 2 workers pulled off in time; never all 16
        assert!(n_ok >= 4);
        pool.close();
        assert_eq!(handled.load(Ordering::SeqCst), n_ok);
    }
}
