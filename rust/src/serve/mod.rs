//! `mxdag serve` — a crash-safe, long-lived multi-tenant coordinator
//! wrapping the open-system streaming driver (`sim/openloop.rs`) in a
//! zero-dependency HTTP service. Four layers:
//!
//! * [`http`] — an HTTP/1.1 subset over `std::net`: size caps
//!   (413/431), read timeouts (408), `Content-Length`-only bodies
//!   (411/501) and a bounded worker pool (queue full ⇒ 503).
//! * [`wal`] — the write-ahead log + snapshot pair. Because era stops
//!   are not bitwise-neutral, the WAL records the *exact call
//!   sequence* (job pushes with bit-exact arrival stamps, advance
//!   targets) and replay re-issues it, landing in bitwise-identical
//!   engine state.
//! * [`service`] — the coordinator: OpenSpec-compatible submissions
//!   planned by the pinned scheduler, per-tenant deferral weights,
//!   watermark admission (429 + Retry-After), periodic snapshot
//!   compaction, graceful drain.
//! * [`server`] — the process: accept loop + SIGTERM flag on the main
//!   thread, a dedicated sim thread owning the [`service::Service`],
//!   `/healthz` `/metrics` `/report` `/jobs` routes, exit codes
//!   0/1/2/3 mirroring `mxdag simulate`.
//!
//! `docs/ARCHITECTURE.md` ("Service mode") documents the WAL record
//! format, the drain state machine and the determinism-on-resume
//! contract; `tests/prop_serve_resume.rs` enforces the bitwise
//! kill/resume property and `tests/serve_http.rs` exercises the real
//! TCP surface end to end.

pub mod http;
pub mod server;
pub mod service;
pub mod wal;

pub use server::run;
pub use service::{Fatal, ServeConfig, Service, SubmitError, Submitted};
