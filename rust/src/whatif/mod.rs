//! What-if analysis (§4.3): evaluate hypothetical application revisions
//! on the MXDAG *before* changing the application — pipelining choices
//! and work re-partitioning — which "are not possible with traditional
//! DAG".

use crate::mxdag::{MXDag, TaskId, TaskKind};
use crate::sched::{evaluate, Plan};
use crate::sim::{Cluster, SimError};

/// Outcome of one hypothetical.
#[derive(Debug, Clone)]
pub struct WhatIf {
    pub label: String,
    pub jct: f64,
    /// JCT delta vs the baseline plan (negative = improvement).
    pub delta: f64,
}

/// Evaluate every single-task pipelining toggle on top of `base`.
/// Returns the baseline JCT and one entry per pipelineable task.
pub fn pipeline_whatif(
    dag: &MXDag,
    cluster: &Cluster,
    base: &Plan,
) -> Result<(f64, Vec<WhatIf>), SimError> {
    let baseline = evaluate(dag, cluster, base)?.makespan;
    let mut out = Vec::new();
    for t in dag.real_tasks() {
        if !dag.task(t).pipelineable() || base.ann.pipelined.contains(&t) {
            continue;
        }
        let mut plan = base.clone();
        plan.ann.pipelined.push(t);
        let jct = evaluate(dag, cluster, &plan)?.makespan;
        out.push(WhatIf {
            label: format!("pipeline({})", dag.task(t).name),
            jct,
            delta: jct - baseline,
        });
    }
    Ok((baseline, out))
}

/// Re-partitioning hypothetical: split compute task `target` into `k`
/// parallel shards on hosts `shard_hosts`, fed by scatter flows from the
/// original host and merged by gather flows back. Returns the revised
/// MXDAG (the original is untouched).
///
/// `scatter`/`gather` are per-shard transfer times; each shard computes
/// `size/k`.
pub fn repartition(
    dag: &MXDag,
    target: TaskId,
    shard_hosts: &[usize],
    scatter: f64,
    gather: f64,
) -> Result<MXDag, String> {
    let t = dag.task(target);
    let TaskKind::Compute { host } = t.kind else {
        return Err(format!("task {} is not a compute task", t.name));
    };
    let k = shard_hosts.len();
    if k < 2 {
        return Err("need at least 2 shards".into());
    }

    let mut b = MXDag::builder();
    let mut map = std::collections::BTreeMap::new();
    for old in dag.tasks() {
        if old.kind.is_dummy() || old.id == target {
            continue;
        }
        let nid = match old.kind {
            TaskKind::Compute { host } => b.compute_full(&old.name, host, old.size, old.unit),
            TaskKind::Flow { src, dst } => b.flow_full(&old.name, src, dst, old.size, old.unit),
            _ => unreachable!(),
        };
        map.insert(old.id, nid);
    }

    // shards + scatter/gather plumbing
    let mut shard_ids = Vec::with_capacity(k);
    for (i, &h) in shard_hosts.iter().enumerate() {
        let sc = if h != host {
            Some(b.flow(&format!("{}_scatter{i}", t.name), host, h, scatter))
        } else {
            None
        };
        let sh = b.compute(&format!("{}_shard{i}", t.name), h, t.size / k as f64);
        let ga = if h != host {
            Some(b.flow(&format!("{}_gather{i}", t.name), h, host, gather))
        } else {
            None
        };
        if let Some(sc) = sc {
            b.dep(sc, sh);
        }
        if let Some(ga) = ga {
            b.dep(sh, ga);
        }
        shard_ids.push((sc, sh, ga));
    }

    // rewire edges
    for old in dag.tasks() {
        if old.kind.is_dummy() {
            continue;
        }
        for &s in dag.succs(old.id) {
            if dag.task(s).kind.is_dummy() {
                continue;
            }
            match (old.id == target, s == target) {
                (false, false) => {
                    b.dep(map[&old.id], map[&s]);
                }
                (true, false) => {
                    // successors wait for every shard's gather (or shard)
                    for &(_, sh, ga) in &shard_ids {
                        b.dep(ga.unwrap_or(sh), map[&s]);
                    }
                }
                (false, true) => {
                    for &(sc, sh, _) in &shard_ids {
                        b.dep(map[&old.id], sc.unwrap_or(sh));
                    }
                }
                (true, true) => unreachable!("self edge"),
            }
        }
    }
    b.finalize().map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{FairScheduler, Scheduler};
    use crate::sim::Cluster;
    use crate::workloads;

    #[test]
    fn pipeline_whatif_signs_match_fig3() {
        let (g, _) = workloads::fig3_dag();
        let cluster = crate::workloads::figs::fig3_cluster();
        let base = Plan { ann: Default::default(), policy: crate::sim::Policy::fifo() };
        let (baseline, results) = pipeline_whatif(&g, &cluster, &base).unwrap();
        assert!(baseline > 0.0);
        let by_label = |l: &str| {
            results
                .iter()
                .find(|w| w.label == format!("pipeline({l})"))
                .unwrap()
        };
        // pipelining D alone (off-critical): no harm
        assert!(by_label("D").delta.abs() < 1e-9);
        // pipelining f3 alone: its stream still queues behind the blocking
        // f1 send (issue order), so nothing changes
        assert!(by_label("f3").delta.abs() < 1e-6);
    }

    #[test]
    fn repartition_splits_compute() {
        let mut b = MXDag::builder();
        let pre = b.compute("pre", 0, 0.5);
        let big = b.compute("big", 0, 8.0);
        let post = b.compute("post", 0, 0.5);
        b.chain(&[pre, big, post]);
        let g = b.finalize().unwrap();

        let g2 = repartition(&g, big, &[0, 1, 2, 3], 0.1, 0.1).unwrap();
        assert!(g2.by_name("big_shard2").is_some());
        assert!(g2.by_name("big").is_none());

        // 4-way split on 4 hosts beats the single 8s task
        let cluster = Cluster::uniform(4);
        let before = evaluate(&g, &cluster, &FairScheduler.plan(&g, &cluster))
            .unwrap()
            .makespan;
        let after = evaluate(&g2, &cluster, &FairScheduler.plan(&g2, &cluster))
            .unwrap()
            .makespan;
        assert!(after < before - 1.0, "split {after} vs mono {before}");
    }

    #[test]
    fn repartition_rejects_flows() {
        let mut b = MXDag::builder();
        let f = b.flow("f", 0, 1, 1.0);
        let g = b.finalize().unwrap();
        assert!(repartition(&g, f, &[0, 1], 0.1, 0.1).is_err());
    }

    #[test]
    fn repartition_needs_two_shards() {
        let mut b = MXDag::builder();
        let c = b.compute("c", 0, 1.0);
        let g = b.finalize().unwrap();
        assert!(repartition(&g, c, &[1], 0.1, 0.1).is_err());
    }
}
