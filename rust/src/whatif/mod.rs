//! What-if analysis (§4.3): evaluate hypotheticals on the MXDAG
//! *before* committing to them — application revisions (pipelining
//! choices and work re-partitioning, which "are not possible with
//! traditional DAG") and *cluster* hypotheticals (a degraded link, a
//! failed parallel fabric) expressed as one-event dynamics timelines
//! (`sim/dynamics.rs`), so a scheduler can ask "what would this plan
//! cost if trunk 1 died?" without mutating the cluster.
//!
//! The batch entry point is [`explore`]: a zero-dependency parallel
//! sweep over [`Hypothetical`]s with per-worker [`EvalContext`]s
//! (cached expansions + reusable engine scratch) and a hard determinism
//! contract — results are **bit-identical for every thread count**,
//! in input order (oracle: `tests/prop_whatif_explore.rs`). A failing
//! hypothetical (invalid revision, invalid link reference, or a
//! variant whose simulation deadlocks — e.g. a degradation that
//! strands a flow with no surviving path) is captured in its own
//! [`WhatIf::outcome`] and never discards the rest of the sweep; only
//! a *baseline* failure aborts, since there is nothing to compare
//! against.

use crate::mxdag::{MXDag, TaskId, TaskKind};
use crate::sched::altruistic::merge;
use crate::sched::mxsched::cpm_on;
use crate::sched::{evaluate, evaluate_with, EvalContext, Plan, SelfishScheduler};
use crate::sim::{
    Annotations, Cluster, CpuPolicy, DynAction, DynTimeline, LinkRef, NetPolicy, RecoveryPolicy,
    SimConfig, SimError,
};
use crate::util::par::par_map_indexed;

/// Outcome of one hypothetical.
#[derive(Debug, Clone)]
pub struct WhatIf {
    pub label: String,
    /// `Ok((jct, delta))` — delta vs the baseline JCT (negative =
    /// improvement) — or this hypothetical's own failure, stringified
    /// (`SimError` for a variant whose simulation deadlocks, or the
    /// revision error, e.g. re-partitioning a flow task).
    pub outcome: Result<(f64, f64), String>,
}

impl WhatIf {
    /// JCT of the hypothetical, if it evaluated.
    pub fn jct(&self) -> Option<f64> {
        self.outcome.as_ref().ok().map(|&(j, _)| j)
    }

    /// JCT delta vs the baseline (negative = improvement), if it
    /// evaluated.
    pub fn delta(&self) -> Option<f64> {
        self.outcome.as_ref().ok().map(|&(_, d)| d)
    }

    /// The captured failure, if the hypothetical did not evaluate.
    pub fn error(&self) -> Option<&str> {
        self.outcome.as_ref().err().map(|s| s.as_str())
    }
}

/// One hypothetical application revision for [`explore`].
#[derive(Debug, Clone)]
pub enum Hypothetical {
    /// Toggle these tasks pipelined on top of the base plan
    /// (non-pipelineable entries are ignored by expansion, as always).
    Pipeline(Vec<TaskId>),
    /// Split compute task `target` into `shard_hosts.len()` parallel
    /// shards fed by scatter/gather flows (see [`repartition`]). The
    /// revised DAG has fresh task ids, so the base plan's per-task
    /// annotations cannot carry over: the variant is scored under the
    /// base *policy*, with priorities re-derived via [`cpm_on`] when
    /// the base policy is priority-based.
    Repartition {
        target: TaskId,
        shard_hosts: Vec<usize>,
        scatter: f64,
        gather: f64,
    },
    /// Cluster hypothetical: score the base plan with `link`'s capacity
    /// scaled by `factor` from t = 0 (a one-event dynamics timeline —
    /// the cluster itself is untouched). `factor: 0.0` asks "what if
    /// this link were down?"; a variant that deadlocks (no surviving
    /// path) captures the error in its own outcome.
    Degrade { link: LinkRef, factor: f64 },
    /// Cluster hypothetical: fail parallel fabric `trunk` at t = 0 and
    /// let the engine re-run `ParallelFabrics` path selection over the
    /// survivors — the cost of losing one fabric plane under the base
    /// plan. Only meaningful on a `ParallelFabrics` cluster (elsewhere
    /// the link validation error is captured in the outcome).
    Reroute { trunk: usize },
    /// Cluster hypothetical: crash `host` at t = `at` and score the base
    /// plan under the default [`RecoveryPolicy::Retry`] — in-flight work
    /// on the host is killed and retried behind backoff gates, and a job
    /// left terminally stuck is quarantined rather than deadlocking the
    /// whole variant (the makespan then covers the *surviving* work).
    /// The asymmetry with [`Hypothetical::Degrade`] is deliberate:
    /// degradations answer "what does this plan cost if capacity
    /// shrinks?" under the oracle FailFast corner, while a crash is
    /// precisely the question the recovery layer exists for.
    FailHost { host: usize, at: f64 },
    /// Admission hypothetical for the open loop (`sim/openloop.rs`):
    /// "what would admitting this job *now* cost the incumbents?" The
    /// incoming DAG is merged next to the base workload
    /// ([`merge`](crate::sched::altruistic::merge)), the mix is scored
    /// under the base *policy* with fresh per-job critical-path
    /// annotations (merge remaps task ids, so the base plan's per-task
    /// annotations cannot carry over — same constraint as
    /// [`Hypothetical::Repartition`]), and the reported JCT is the
    /// *incumbents'* completion time under contention; the delta vs the
    /// baseline is the admission cost an admission controller weighs
    /// against the arrival's deadline.
    Admit { job: Box<MXDag> },
}

impl Hypothetical {
    /// Stable human-readable label (identical across thread counts).
    pub fn label(&self, dag: &MXDag) -> String {
        match self {
            Hypothetical::Pipeline(ts) => {
                let names: Vec<&str> =
                    ts.iter().map(|&t| dag.task(t).name.as_str()).collect();
                format!("pipeline({})", names.join("+"))
            }
            Hypothetical::Repartition { target, shard_hosts, .. } => {
                format!("repartition({} x{})", dag.task(*target).name, shard_hosts.len())
            }
            Hypothetical::Degrade { link, factor } => {
                format!("degrade({},x{factor})", link.label())
            }
            Hypothetical::Reroute { trunk } => format!("reroute(-trunk:{trunk})"),
            Hypothetical::FailHost { host, at } => format!("fail_host({host}@{at})"),
            Hypothetical::Admit { job } => format!("admit(+{} tasks)", job.len()),
        }
    }
}

/// Result of an [`explore`] sweep.
#[derive(Debug, Clone)]
pub struct Exploration {
    /// JCT of the base plan.
    pub baseline: f64,
    /// One entry per hypothetical, in input order.
    pub results: Vec<WhatIf>,
}

/// Batched what-if exploration: score every hypothetical against the
/// baseline, fanned across `threads` workers (`std::thread::scope`
/// via [`par_map_indexed`]; `1` runs inline and spawns nothing). Each
/// worker owns an [`EvalContext`], so evaluation `k+1` on a worker
/// reuses cached expansions, cluster footprints and engine scratch —
/// scoring a plan costs only its simulation.
///
/// Determinism contract: every hypothetical is a pure function of
/// `(dag, cluster, base)` and results are returned in input order, so
/// the output — baseline, labels, JCTs, bit for bit — is identical for
/// every `threads` value.
pub fn explore(
    dag: &MXDag,
    cluster: &Cluster,
    base: &Plan,
    hypos: &[Hypothetical],
    threads: usize,
) -> Result<Exploration, SimError> {
    let mut base_ctx = EvalContext::new(dag, cluster);
    let baseline = base_ctx.evaluate(base)?.makespan;
    // the baseline context becomes worker 0's state instead of being
    // dropped — the serial sweep in particular runs entirely warm
    let mut base_ctx = Some(base_ctx);
    let results = par_map_indexed(
        hypos,
        threads,
        move || base_ctx.take().unwrap_or_else(|| EvalContext::new(dag, cluster)),
        |ctx, _, h| eval_hypothetical(ctx, base, baseline, h),
    );
    Ok(Exploration { baseline, results })
}

/// Score one hypothetical — a pure function of
/// `(ctx.dag, ctx.cluster, base, h)`; the context only caches.
fn eval_hypothetical(
    ctx: &mut EvalContext<'_>,
    base: &Plan,
    baseline: f64,
    h: &Hypothetical,
) -> WhatIf {
    let label = h.label(ctx.dag());
    let jct: Result<f64, String> = match h {
        Hypothetical::Pipeline(ts) => {
            let mut trial = base.clone();
            for &t in ts {
                if !trial.ann.pipelined.contains(&t) {
                    trial.ann.pipelined.push(t);
                }
            }
            ctx.evaluate(&trial).map(|r| r.makespan).map_err(|e| e.to_string())
        }
        Hypothetical::Repartition { target, shard_hosts, scatter, gather } => {
            repartition(ctx.dag(), *target, shard_hosts, *scatter, *gather).and_then(|g2| {
                let mut ann = Annotations::default();
                // any priority-bearing policy (cpu or net side) needs
                // fresh priorities, or strict-priority queues would run
                // on all-zero ranks and the delta would conflate the
                // repartition with an annotation change
                if base.policy.cpu == CpuPolicy::Priority
                    || base.policy.net == NetPolicy::Priority
                {
                    let prios = cpm_on(&g2, ctx.cluster()).priorities();
                    for t in g2.real_tasks() {
                        ann.priorities.insert(t, prios[t]);
                    }
                }
                let plan = Plan { ann, policy: base.policy };
                evaluate(&g2, ctx.cluster(), &plan)
                    .map(|r| r.makespan)
                    .map_err(|e| e.to_string())
            })
        }
        Hypothetical::Degrade { link, factor } => cluster_jct(
            ctx,
            base,
            DynTimeline::new().with(0.0, DynAction::Degrade { link: *link, factor: *factor }),
            RecoveryPolicy::FailFast,
        ),
        Hypothetical::Reroute { trunk } => cluster_jct(
            ctx,
            base,
            DynTimeline::new()
                .with(0.0, DynAction::Degrade { link: LinkRef::Trunk(*trunk), factor: 0.0 }),
            RecoveryPolicy::FailFast,
        ),
        Hypothetical::FailHost { host, at } => cluster_jct(
            ctx,
            base,
            DynTimeline::new().with(*at, DynAction::FailHost { host: *host }),
            RecoveryPolicy::retry_default(),
        ),
        Hypothetical::Admit { job } => {
            let multi = merge(&[ctx.dag().clone(), (**job).clone()]);
            // fresh per-job CPM annotations over the mix (merge remaps
            // task ids), scored under the base policy
            let ann = SelfishScheduler.plan_multi(&multi).ann;
            let plan = Plan { ann, policy: base.policy };
            evaluate(&multi.dag, ctx.cluster(), &plan)
                .map(|r| multi.jct(0, &r))
                .map_err(|e| e.to_string())
        }
    };
    WhatIf { label, outcome: jct.map(|j| (j, j - baseline)) }
}

/// Score the base plan under a hypothetical dynamics timeline. Invalid
/// link references and deadlocking variants both surface as `Err` —
/// the sweep-level contract that cluster hypotheticals must never
/// poison the exploration.
fn cluster_jct(
    ctx: &mut EvalContext<'_>,
    base: &Plan,
    timeline: DynTimeline,
    recovery: RecoveryPolicy,
) -> Result<f64, String> {
    timeline.validate(ctx.cluster())?;
    let cfg = SimConfig { dynamics: timeline, recovery, ..SimConfig::default() };
    evaluate_with(ctx.dag(), ctx.cluster(), base, &cfg)
        .map(|r| r.makespan)
        .map_err(|e| e.to_string())
}

/// The §4.3 candidate set: one [`Hypothetical::Pipeline`] per
/// pipelineable task not already pipelined by `base`, in task order.
pub fn single_pipeline_toggles(dag: &MXDag, base: &Plan) -> Vec<Hypothetical> {
    dag.real_tasks()
        .filter(|&t| dag.task(t).pipelineable() && !base.ann.pipelined.contains(&t))
        .map(|t| Hypothetical::Pipeline(vec![t]))
        .collect()
}

/// Evaluate every single-task pipelining toggle on top of `base` — the
/// classic §4.3 sweep, now a serial [`explore`] call. Returns the
/// baseline JCT and one entry per pipelineable task; a failing toggle
/// is captured in its entry (see [`WhatIf::outcome`]), never
/// propagated.
pub fn pipeline_whatif(
    dag: &MXDag,
    cluster: &Cluster,
    base: &Plan,
) -> Result<(f64, Vec<WhatIf>), SimError> {
    let hypos = single_pipeline_toggles(dag, base);
    let ex = explore(dag, cluster, base, &hypos, 1)?;
    Ok((ex.baseline, ex.results))
}

/// Re-partitioning hypothetical: split compute task `target` into `k`
/// parallel shards on hosts `shard_hosts`, fed by scatter flows from the
/// original host and merged by gather flows back. Returns the revised
/// MXDAG (the original is untouched).
///
/// `scatter`/`gather` are per-shard transfer times; each shard computes
/// `size/k`.
pub fn repartition(
    dag: &MXDag,
    target: TaskId,
    shard_hosts: &[usize],
    scatter: f64,
    gather: f64,
) -> Result<MXDag, String> {
    let t = dag.task(target);
    let TaskKind::Compute { host } = t.kind else {
        return Err(format!("task {} is not a compute task", t.name));
    };
    let k = shard_hosts.len();
    if k < 2 {
        return Err("need at least 2 shards".into());
    }

    let mut b = MXDag::builder();
    let mut map = std::collections::BTreeMap::new();
    for old in dag.tasks() {
        if old.kind.is_dummy() || old.id == target {
            continue;
        }
        let nid = match old.kind {
            TaskKind::Compute { host } => b.compute_full(&old.name, host, old.size, old.unit),
            TaskKind::Flow { src, dst } => b.flow_full(&old.name, src, dst, old.size, old.unit),
            _ => unreachable!(),
        };
        map.insert(old.id, nid);
    }

    // shards + scatter/gather plumbing
    let mut shard_ids = Vec::with_capacity(k);
    for (i, &h) in shard_hosts.iter().enumerate() {
        let sc = if h != host {
            Some(b.flow(&format!("{}_scatter{i}", t.name), host, h, scatter))
        } else {
            None
        };
        let sh = b.compute(&format!("{}_shard{i}", t.name), h, t.size / k as f64);
        let ga = if h != host {
            Some(b.flow(&format!("{}_gather{i}", t.name), h, host, gather))
        } else {
            None
        };
        if let Some(sc) = sc {
            b.dep(sc, sh);
        }
        if let Some(ga) = ga {
            b.dep(sh, ga);
        }
        shard_ids.push((sc, sh, ga));
    }

    // rewire edges
    for old in dag.tasks() {
        if old.kind.is_dummy() {
            continue;
        }
        for &s in dag.succs(old.id) {
            if dag.task(s).kind.is_dummy() {
                continue;
            }
            match (old.id == target, s == target) {
                (false, false) => {
                    b.dep(map[&old.id], map[&s]);
                }
                (true, false) => {
                    // successors wait for every shard's gather (or shard)
                    for &(_, sh, ga) in &shard_ids {
                        b.dep(ga.unwrap_or(sh), map[&s]);
                    }
                }
                (false, true) => {
                    for &(sc, sh, _) in &shard_ids {
                        b.dep(map[&old.id], sc.unwrap_or(sh));
                    }
                }
                (true, true) => unreachable!("self edge"),
            }
        }
    }
    b.finalize().map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{FairScheduler, Scheduler};
    use crate::sim::Cluster;
    use crate::workloads;

    #[test]
    fn pipeline_whatif_signs_match_fig3() {
        let (g, _) = workloads::fig3_dag();
        let cluster = crate::workloads::figs::fig3_cluster();
        let base = Plan { ann: Default::default(), policy: crate::sim::Policy::fifo() };
        let (baseline, results) = pipeline_whatif(&g, &cluster, &base).unwrap();
        assert!(baseline > 0.0);
        let by_label = |l: &str| {
            results
                .iter()
                .find(|w| w.label == format!("pipeline({l})"))
                .unwrap()
        };
        // pipelining D alone (off-critical): no harm
        assert!(by_label("D").delta.unwrap().abs() < 1e-9);
        // pipelining f3 alone: its stream still queues behind the blocking
        // f1 send (issue order), so nothing changes
        assert!(by_label("f3").delta.unwrap().abs() < 1e-6);
    }

    /// The satellite bugfix: one failing hypothetical must not abort
    /// the sweep. An invalid revision (re-partitioning a flow, too few
    /// shards), a *deadlocking* variant (scatter into a dead NIC), and
    /// failing cluster hypotheticals (a degradation that strands the
    /// flow, a link reference this topology doesn't have) each capture
    /// their own error while the healthy hypotheticals around them
    /// still score.
    #[test]
    fn failing_hypotheticals_do_not_abort_the_sweep() {
        let mut b = MXDag::builder();
        let pre = b.compute("pre", 0, 0.5);
        let big = b.compute_full("big", 0, 8.0, 1.0);
        let f = b.flow("f", 0, 1, 1.0);
        b.dep(pre, big).dep(big, f);
        let g = b.finalize().unwrap();
        // host 2 exists but its NICs are dead: any variant that routes
        // a flow through it deadlocks, while the baseline never does
        let mut cluster = Cluster::uniform(3);
        cluster.hosts[2].nic_up = 0.0;
        cluster.hosts[2].nic_down = 0.0;
        let base = Plan::fair();
        let hypos = vec![
            Hypothetical::Pipeline(vec![big]),
            Hypothetical::Repartition {
                target: f, // flow: invalid revision
                shard_hosts: vec![0, 1],
                scatter: 0.1,
                gather: 0.1,
            },
            Hypothetical::Repartition {
                target: big, // scatter 0 -> 2 starves: deadlock
                shard_hosts: vec![0, 2],
                scatter: 0.1,
                gather: 0.1,
            },
            Hypothetical::Repartition {
                target: big,
                shard_hosts: vec![0], // too few shards
                scatter: 0.1,
                gather: 0.1,
            },
            Hypothetical::Repartition {
                target: big, // healthy split across live hosts
                shard_hosts: vec![0, 1],
                scatter: 0.1,
                gather: 0.1,
            },
            // cluster hypotheticals: killing the flow's own uplink
            // deadlocks (captured), a trunk reference doesn't resolve
            // on a big switch (captured), halving the uplink scores
            Hypothetical::Degrade { link: LinkRef::NicUp(0), factor: 0.0 },
            Hypothetical::Reroute { trunk: 0 },
            Hypothetical::Degrade { link: LinkRef::NicUp(0), factor: 0.5 },
        ];
        let ex = explore(&g, &cluster, &base, &hypos, 1).unwrap();
        assert_eq!(ex.results.len(), hypos.len());
        assert!(ex.results[0].jct().is_some(), "pipeline toggle scores");
        assert!(ex.results[1].error().unwrap().contains("not a compute task"));
        assert!(
            ex.results[2].error().unwrap().contains("deadlock"),
            "deadlocking variant is captured, not propagated: {:?}",
            ex.results[2]
        );
        assert!(ex.results[3].error().unwrap().contains("at least 2 shards"));
        let healthy = &ex.results[4];
        assert!(
            healthy.delta().unwrap() < -1.0,
            "the split past the failures still scores: {healthy:?}"
        );
        assert!(
            ex.results[5].error().unwrap().contains("deadlock"),
            "a degradation that strands the flow is captured: {:?}",
            ex.results[5]
        );
        assert!(
            ex.results[6].error().unwrap().contains("trunk"),
            "bad link reference is captured: {:?}",
            ex.results[6]
        );
        let slower = &ex.results[7];
        assert!(
            slower.delta().unwrap() > 0.5,
            "half uplink capacity must slow the flow: {slower:?}"
        );
    }

    /// Reroute hypotheticals on a parallel-fabric cluster: failing a
    /// trunk re-picks every flow over the survivors — colliding flows
    /// slow down, a symmetric re-pick costs nothing — and failing the
    /// only trunk of a k = 1 fabric deadlocks and is captured
    /// per-hypothetical.
    #[test]
    fn reroute_hypotheticals_score_surviving_fabrics() {
        let mut b = MXDag::builder();
        let f = b.flow("f", 1, 0, 2.0); // hash pick: (1+0) % 3 = 1
        let h = b.flow("h", 0, 2, 2.0); // hash pick: (0+2) % 3 = 2
        let _ = (f, h);
        let g = b.finalize().unwrap();
        let cluster = Cluster::parallel_fabrics(3, 3, 1.0);
        let base = Plan::fair();
        let hypos = vec![
            // survivors [0, 2]: both flows re-pick trunk 0 and collide
            Hypothetical::Reroute { trunk: 1 },
            // survivors [1, 2]: the flows swap trunks — same cost
            Hypothetical::Reroute { trunk: 0 },
        ];
        let ex = explore(&g, &cluster, &base, &hypos, 1).unwrap();
        let collided = &ex.results[0];
        assert!(
            collided.delta().unwrap() > 0.5,
            "two flows sharing one survivor must slow down: {collided:?}"
        );
        let swapped = &ex.results[1];
        assert_eq!(
            swapped.jct().unwrap().to_bits(),
            ex.baseline.to_bits(),
            "a symmetric re-pick over identical trunks is free: {swapped:?}"
        );

        // k = 1: the only trunk dying strands every flow — captured
        let one = Cluster::parallel_fabrics(3, 1, 1.0);
        let ex = explore(
            &g,
            &one,
            &base,
            &[Hypothetical::Reroute { trunk: 0 }],
            1,
        )
        .unwrap();
        assert!(
            ex.results[0].error().unwrap().contains("deadlock"),
            "no surviving path: {:?}",
            ex.results[0]
        );
    }

    /// A `FailHost` hypothetical scores under the Retry policy: a crash
    /// that dooms one job quarantines it instead of deadlocking the
    /// variant, so the JCT covers the surviving jobs — while a crash
    /// scheduled past the makespan never fires and scores as a no-op.
    #[test]
    fn fail_host_hypothetical_scores_surviving_jobs() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 4.0);
        let c = b.compute("c", 1, 4.0);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let mut base = Plan::fair();
        base.ann.jobs.insert(a, 0);
        base.ann.jobs.insert(c, 1);
        let hypos = vec![
            Hypothetical::FailHost { host: 1, at: 1.0 },
            Hypothetical::FailHost { host: 0, at: 100.0 },
        ];
        let ex = explore(&g, &cluster, &base, &hypos, 1).unwrap();
        assert_eq!(ex.results[0].label, "fail_host(1@1)");
        // host 1's job is quarantined (its core is gone for good); the
        // score is job 0's unperturbed completion, not a deadlock
        let jct = ex.results[0].jct().expect("crash variant must score");
        assert!((jct - 4.0).abs() < 1e-9, "surviving job sets the JCT: {jct}");
        // a crash after everything finished changes nothing
        assert!(ex.results[1].delta().unwrap().abs() < 1e-9, "{:?}", ex.results[1]);
    }

    /// Unit-level determinism slice of the parallel oracle (the full
    /// random sweep lives in `tests/prop_whatif_explore.rs`): thread
    /// counts must not change a single bit of the exploration.
    #[test]
    fn explore_parallel_matches_serial() {
        let (g, _) = workloads::fig3_dag();
        let cluster = crate::workloads::figs::fig3_cluster();
        let base = Plan { ann: Default::default(), policy: crate::sim::Policy::fifo() };
        let hypos = single_pipeline_toggles(&g, &base);
        assert!(hypos.len() >= 2, "fig3 has pipelineable tasks");
        let serial = explore(&g, &cluster, &base, &hypos, 1).unwrap();
        for threads in [2, 3, 16] {
            let par = explore(&g, &cluster, &base, &hypos, threads).unwrap();
            assert_eq!(serial.baseline.to_bits(), par.baseline.to_bits());
            assert_eq!(serial.results.len(), par.results.len());
            for (a, b) in serial.results.iter().zip(par.results.iter()) {
                assert_eq!(a.label, b.label);
                match (&a.outcome, &b.outcome) {
                    (Ok((ja, da)), Ok((jb, db))) => {
                        assert_eq!(ja.to_bits(), jb.to_bits());
                        assert_eq!(da.to_bits(), db.to_bits());
                    }
                    (Err(ea), Err(eb)) => assert_eq!(ea, eb),
                    (x, y) => panic!("outcome kind diverged: {x:?} vs {y:?}"),
                }
            }
        }
    }

    /// Admission hypotheticals report the *incumbents'* completion under
    /// the mix: a colliding arrival halves the incumbent's rate (fair
    /// sharing), a disjoint arrival costs nothing — the exact signal an
    /// open-loop admission controller wants before committing.
    #[test]
    fn admit_hypothetical_prices_contention_for_incumbents() {
        let mut b = MXDag::builder();
        b.compute("incumbent", 0, 4.0);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let base = Plan::fair();

        let mut b = MXDag::builder();
        b.compute("collider", 0, 4.0);
        let colliding = b.finalize().unwrap();
        let mut b = MXDag::builder();
        b.compute("neighbour", 1, 4.0);
        let disjoint = b.finalize().unwrap();

        let hypos = vec![
            Hypothetical::Admit { job: Box::new(colliding) },
            Hypothetical::Admit { job: Box::new(disjoint) },
        ];
        let ex = explore(&g, &cluster, &base, &hypos, 1).unwrap();
        assert!((ex.baseline - 4.0).abs() < 1e-9);
        assert_eq!(ex.results[0].label, "admit(+1 tasks)");
        // fair sharing on host 0's core: the incumbent drops to half rate
        assert!(
            (ex.results[0].delta().unwrap() - 4.0).abs() < 1e-9,
            "colliding admit doubles the incumbent JCT: {:?}",
            ex.results[0]
        );
        // the disjoint arrival never contends with the incumbent
        assert!(
            ex.results[1].delta().unwrap().abs() < 1e-9,
            "disjoint admit is free for incumbents: {:?}",
            ex.results[1]
        );
    }

    #[test]
    fn repartition_splits_compute() {
        let mut b = MXDag::builder();
        let pre = b.compute("pre", 0, 0.5);
        let big = b.compute("big", 0, 8.0);
        let post = b.compute("post", 0, 0.5);
        b.chain(&[pre, big, post]);
        let g = b.finalize().unwrap();

        let g2 = repartition(&g, big, &[0, 1, 2, 3], 0.1, 0.1).unwrap();
        assert!(g2.by_name("big_shard2").is_some());
        assert!(g2.by_name("big").is_none());

        // 4-way split on 4 hosts beats the single 8s task
        let cluster = Cluster::uniform(4);
        let before = evaluate(&g, &cluster, &FairScheduler.plan(&g, &cluster))
            .unwrap()
            .makespan;
        let after = evaluate(&g2, &cluster, &FairScheduler.plan(&g2, &cluster))
            .unwrap()
            .makespan;
        assert!(after < before - 1.0, "split {after} vs mono {before}");
    }

    #[test]
    fn repartition_rejects_flows() {
        let mut b = MXDag::builder();
        let f = b.flow("f", 0, 1, 1.0);
        let g = b.finalize().unwrap();
        assert!(repartition(&g, f, &[0, 1], 0.1, 0.1).is_err());
    }

    #[test]
    fn repartition_needs_two_shards() {
        let mut b = MXDag::builder();
        let c = b.compute("c", 0, 1.0);
        let g = b.finalize().unwrap();
        assert!(repartition(&g, c, &[1], 0.1, 0.1).is_err());
    }
}
