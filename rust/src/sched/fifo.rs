//! Plain-DAG baseline: per-resource FIFO (§2.1).
//!
//! Models Spark/Dryad-style systems that treat network transfer as an
//! opaque part of the task: flows are served in readiness order on each
//! NIC, computations in readiness order on each host — no notion of
//! which flow is critical.

use super::{Plan, Scheduler};
use crate::mxdag::MXDag;
use crate::sim::{Annotations, Cluster, Policy, QueueDiscipline};

/// The plain-DAG FIFO baseline scheduler.
#[derive(Debug, Clone, Copy, Default)]
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }
    fn plan(&self, _dag: &MXDag, _cluster: &Cluster) -> Plan {
        Plan { ann: Annotations::default(), policy: Policy::fifo() }
    }
    /// Arrival-order slots, assigned by the engine at first readiness;
    /// once assigned, keys never go stale.
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::FIFO]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::run;
    use crate::sim::Cluster;

    #[test]
    fn fifo_serializes_in_ready_order() {
        // Both flows ready at t=0 from host 0; FIFO runs them back to back
        // (2 units total), not in parallel halves — same completion for the
        // last, but the first finishes at 1.
        let mut b = MXDag::builder();
        let f1 = b.flow("f1", 0, 1, 1.0);
        let f2 = b.flow("f2", 0, 2, 1.0);
        let g = b.finalize().unwrap();
        let r = run(&FifoScheduler, &g, &Cluster::uniform(3)).unwrap();
        let t1 = r.finish_of(f1);
        let t2 = r.finish_of(f2);
        assert!((t1.min(t2) - 1.0).abs() < 1e-9, "one flow must finish at 1");
        assert!((t1.max(t2) - 2.0).abs() < 1e-9);
    }
}
