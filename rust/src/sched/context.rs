//! Reusable evaluation contexts — the sched/sim boundary of the
//! batched plan-space engine.
//!
//! What-if analysis (§4.3) and MxScheduler's pipeline search score
//! *many* plans against one `(dag, cluster)` pair. A cold
//! [`evaluate`](crate::sched::evaluate) pays, per plan: the DAG
//! expansion (chunking + dependency rewiring), the cluster arena setup
//! (capacities and per-chunk resource footprints), and the allocation
//! of every engine buffer. An [`EvalContext`] amortises all three:
//!
//! * **Expansion cache** — the chunk *structure* of an expansion
//!   depends only on the plan's (canonicalised) pipelined set, so it is
//!   cached per distinct set (LRU, [`MAX_CACHED_EXPANSIONS`] entries)
//!   together with the cluster-derived per-chunk footprints. Per-task
//!   annotation fields (priority, gate, coflow tag) are cheap value
//!   rewrites, re-applied to the cached chunks on every evaluation —
//!   exactly the assignments [`expand`] performs.
//! * **Arena cache** — [`Cluster::capacities`] is computed once per
//!   context.
//! * **Engine scratch** — one [`SimScratch`] is reset (not reallocated)
//!   between runs, so plan `k+1` costs only the simulation itself.
//!   Since the parallel event loop this scratch also carries the
//!   engine's per-worker refill arenas, so a context whose
//!   [`SimConfig`] sets `threads > 1` keeps those workers' buffers
//!   warm across every plan it scores (the `threads` axis flows into
//!   each evaluation through the context's config like every other
//!   engine knob).
//!
//! Results are bit-for-bit identical to the cold path (asserted by
//! `context_matches_cold_evaluate_bitwise` below and by the parallel
//! what-if oracle in `tests/prop_whatif_explore.rs`): the context is a
//! cost optimisation, never a semantics change. A context borrows its
//! `(dag, cluster)` — plans for a *different* DAG need a different
//! context (what-if repartitions build one per revised DAG).

use super::Plan;
use crate::mxdag::{MXDag, TaskId};
use crate::sim::{
    apply_annotations, expand, simulate_with_footprints, Annotations, Cluster, SimConfig,
    SimDag, SimError, SimResult, SimScratch, TaskRes,
};

/// Expansion-cache capacity per context. Greedy pipeline search tries
/// at most `max_moves` (64) distinct sets; sweeps past the cap evict
/// least-recently-used entries (each hypothetical touches its set once,
/// so eviction costs nothing there).
pub const MAX_CACHED_EXPANSIONS: usize = 64;

/// One cached expansion: the chunk structure for a canonical pipelined
/// set, plus the cluster-derived per-chunk arrays the engine core
/// takes as inputs.
struct CachedExpansion {
    key: Vec<TaskId>,
    sim: SimDag,
    task_res: Vec<TaskRes>,
    is_flow: Vec<bool>,
    stamp: u64,
}

/// Reusable evaluation context for one `(dag, cluster)` pair. See the
/// module docs; construct with [`EvalContext::new`] (default engine
/// configuration) or [`EvalContext::with_config`].
pub struct EvalContext<'a> {
    dag: &'a MXDag,
    cluster: &'a Cluster,
    cfg: SimConfig,
    caps0: Vec<f64>,
    scratch: SimScratch,
    cache: Vec<CachedExpansion>,
    clock: u64,
    key_buf: Vec<TaskId>,
}

impl<'a> EvalContext<'a> {
    /// Context with the default engine configuration.
    pub fn new(dag: &'a MXDag, cluster: &'a Cluster) -> EvalContext<'a> {
        EvalContext::with_config(dag, cluster, SimConfig::default())
    }

    /// Context with explicit engine knobs (queue / alloc / horizon /
    /// event budget). `cfg.policy` is overridden per evaluation by each
    /// plan's policy, as in [`crate::sched::evaluate_with`].
    pub fn with_config(dag: &'a MXDag, cluster: &'a Cluster, cfg: SimConfig) -> EvalContext<'a> {
        EvalContext {
            dag,
            cluster,
            cfg,
            caps0: cluster.capacities(),
            scratch: SimScratch::default(),
            cache: Vec::new(),
            clock: 0,
            key_buf: Vec::new(),
        }
    }

    pub fn dag(&self) -> &'a MXDag {
        self.dag
    }

    pub fn cluster(&self) -> &'a Cluster {
        self.cluster
    }

    /// Number of expansions currently cached (diagnostics / tests).
    pub fn cached_expansions(&self) -> usize {
        self.cache.len()
    }

    /// Expand + simulate `plan`, reusing cached structure and engine
    /// scratch. Bit-identical to
    /// `evaluate_with(dag, cluster, plan, cfg)`.
    pub fn evaluate(&mut self, plan: &Plan) -> Result<SimResult, SimError> {
        // canonical pipelined set: order, duplicates and
        // non-pipelineable entries don't affect the expansion
        let dag = self.dag;
        self.key_buf.clear();
        self.key_buf.extend(
            plan.ann.pipelined.iter().copied().filter(|&t| dag.task(t).pipelineable()),
        );
        self.key_buf.sort_unstable();
        self.key_buf.dedup();
        let idx = match self.cache.iter().position(|e| e.key == self.key_buf) {
            Some(i) => i,
            None => {
                // expand the structure once per distinct pipelined set;
                // per-task fields are (re)applied below
                let structure = Annotations {
                    pipelined: self.key_buf.clone(),
                    ..Default::default()
                };
                let sim = expand(dag, &structure);
                let task_res: Vec<TaskRes> =
                    sim.tasks.iter().map(|t| self.cluster.task_res(&t.kind)).collect();
                let is_flow: Vec<bool> = sim.tasks.iter().map(|t| t.kind.is_flow()).collect();
                if self.cache.len() >= MAX_CACHED_EXPANSIONS {
                    let lru = self
                        .cache
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, e)| e.stamp)
                        .map(|(i, _)| i)
                        .expect("cache is non-empty");
                    self.cache.swap_remove(lru);
                }
                self.cache.push(CachedExpansion {
                    key: self.key_buf.clone(),
                    sim,
                    task_res,
                    is_flow,
                    stamp: 0,
                });
                self.cache.len() - 1
            }
        };
        self.clock += 1;
        let entry = &mut self.cache[idx];
        entry.stamp = self.clock;

        // (re)apply the plan's per-task annotations to the cached
        // chunks — the exact field semantics `expand` uses, shared
        // through `sim::apply_annotations`
        #[cfg(debug_assertions)]
        for mem in plan.ann.coflows.iter() {
            for m in mem {
                debug_assert!(
                    !entry.key.contains(m),
                    "coflow semantics are defined on unpipelined flows"
                );
            }
        }
        apply_annotations(&mut entry.sim, &plan.ann);

        let cfg = SimConfig { policy: plan.policy, ..self.cfg.clone() };
        simulate_with_footprints(
            &entry.sim,
            self.cluster,
            &cfg,
            &entry.task_res,
            &entry.is_flow,
            &self.caps0,
            &mut self.scratch,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{evaluate_with, CoflowScheduler, Grouping, MxScheduler, Plan, Scheduler};
    use crate::sim::Policy;
    use crate::workloads::{random_dag, RandomParams};

    fn assert_bits(a: &SimResult, b: &SimResult) {
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.trace.len(), b.trace.len());
        for i in 0..a.trace.len() {
            assert_eq!(a.trace[i].start.to_bits(), b.trace[i].start.to_bits());
            assert_eq!(a.trace[i].finish.to_bits(), b.trace[i].finish.to_bits());
        }
    }

    /// The context contract: whatever ran before on the context, every
    /// evaluation is bit-identical to the cold path — across plan
    /// families (fair, priority, coflow groups, pipelined sets) on a
    /// random DAG, interleaved to force cache hits, misses and
    /// annotation rewrites on shared structure.
    #[test]
    fn context_matches_cold_evaluate_bitwise() {
        let p = RandomParams { layers: 5, width: 4, hosts: 6, seed: 13, ..Default::default() };
        let g = random_dag(&p);
        let cluster = crate::sim::Cluster::uniform(p.hosts);
        let piped: Vec<TaskId> =
            g.real_tasks().filter(|&t| g.task(t).pipelineable()).collect();

        let mut plans: Vec<Plan> = vec![
            Plan::fair(),
            MxScheduler::without_pipelining().plan(&g, &cluster),
            CoflowScheduler::new(Grouping::ByDst).plan(&g, &cluster),
        ];
        // pipelined variants: same structure key evaluated under two
        // different policies, plus a growing set
        if let Some(&t0) = piped.first() {
            let mut fifo = Plan { ann: Default::default(), policy: Policy::fifo() };
            fifo.ann.pipelined.push(t0);
            plans.push(fifo.clone());
            let mut fair = fifo.clone();
            fair.policy = Policy::fair();
            plans.push(fair);
            let mut grown = fifo;
            grown.ann.pipelined.extend(piped.iter().copied());
            plans.push(grown);
        }

        let mut ctx = EvalContext::new(&g, &cluster);
        // two passes: the second hits a fully warm cache + scratch
        for _ in 0..2 {
            for plan in &plans {
                let cold = evaluate_with(&g, &cluster, plan, &SimConfig::default()).unwrap();
                let warm = ctx.evaluate(plan).unwrap();
                assert_bits(&cold, &warm);
            }
        }
    }

    /// Distinct pipelined sets get distinct cache entries; permutations
    /// and duplicates of one set share a single entry.
    #[test]
    fn expansion_cache_keys_are_canonical() {
        let p = RandomParams { seed: 21, ..Default::default() };
        let g = random_dag(&p);
        let cluster = crate::sim::Cluster::uniform(p.hosts);
        let piped: Vec<TaskId> =
            g.real_tasks().filter(|&t| g.task(t).pipelineable()).collect();
        if piped.len() < 2 {
            return; // seed guarantees ≥ 2 in practice; stay robust
        }
        let mut ctx = EvalContext::new(&g, &cluster);
        let mk = |set: Vec<TaskId>| Plan {
            ann: Annotations { pipelined: set, ..Default::default() },
            policy: Policy::fair(),
        };
        ctx.evaluate(&mk(vec![])).unwrap();
        assert_eq!(ctx.cached_expansions(), 1);
        ctx.evaluate(&mk(vec![piped[0], piped[1]])).unwrap();
        assert_eq!(ctx.cached_expansions(), 2);
        // permuted + duplicated spelling of the same set: cache hit
        ctx.evaluate(&mk(vec![piped[1], piped[0], piped[1]])).unwrap();
        assert_eq!(ctx.cached_expansions(), 2);
        ctx.evaluate(&mk(vec![piped[0]])).unwrap();
        assert_eq!(ctx.cached_expansions(), 3);
    }
}
