//! Coflow baseline (§2.2): Varys-style SEBF + MADD with all-or-nothing
//! semantics, and the *grouping ambiguity* of Fig. 2(b1,b2,b3) made
//! explicit as a pluggable strategy.

use std::collections::BTreeMap;

use super::{Plan, Scheduler};
use crate::mxdag::{MXDag, TaskId};
use crate::sim::{Annotations, Cluster, Policy, QueueDiscipline};

/// How flows are grouped into coflows — the definitional choice the
/// application programmer "must commit to" per §2.2.
#[derive(Debug, Clone)]
pub enum Grouping {
    /// Hand-specified groups (used for Fig. 2's b1/b2/b3 variants).
    Explicit(Vec<Vec<TaskId>>),
    /// Aggregation view: flows sharing a destination compute task.
    ByDst,
    /// Broadcast view: flows sharing a source compute task.
    BySrc,
    /// Stage view: flows at the same topological depth form one coflow.
    ByLevel,
}

/// The Varys-style coflow baseline scheduler.
#[derive(Debug, Clone)]
pub struct CoflowScheduler {
    /// How flows are grouped into coflows (see [`Grouping`]).
    pub grouping: Grouping,
}

impl CoflowScheduler {
    pub fn new(grouping: Grouping) -> Self {
        CoflowScheduler { grouping }
    }

    /// Derive the coflow groups for `dag` under this grouping.
    pub fn groups(&self, dag: &MXDag) -> Vec<Vec<TaskId>> {
        let flows: Vec<TaskId> = dag
            .real_tasks()
            .filter(|&t| dag.task(t).kind.is_flow())
            .collect();
        match &self.grouping {
            Grouping::Explicit(groups) => groups.clone(),
            Grouping::ByDst => {
                let mut by: BTreeMap<Vec<TaskId>, Vec<TaskId>> = BTreeMap::new();
                for &f in &flows {
                    by.entry(dag.succs(f).to_vec()).or_default().push(f);
                }
                by.into_values().collect()
            }
            Grouping::BySrc => {
                let mut by: BTreeMap<Vec<TaskId>, Vec<TaskId>> = BTreeMap::new();
                for &f in &flows {
                    by.entry(dag.preds(f).to_vec()).or_default().push(f);
                }
                by.into_values().collect()
            }
            Grouping::ByLevel => {
                // topological depth of each task
                let mut depth = vec![0usize; dag.len()];
                for &u in dag.topo() {
                    for &v in dag.succs(u) {
                        depth[v] = depth[v].max(depth[u] + 1);
                    }
                }
                let mut by: BTreeMap<usize, Vec<TaskId>> = BTreeMap::new();
                for &f in &flows {
                    by.entry(depth[f]).or_default().push(f);
                }
                by.into_values().collect()
            }
        }
    }
}

impl Scheduler for CoflowScheduler {
    fn name(&self) -> &'static str {
        "coflow"
    }
    fn plan(&self, dag: &MXDag, _cluster: &Cluster) -> Plan {
        Plan {
            ann: Annotations { coflows: self.groups(dag), ..Default::default() },
            policy: Policy::coflow(),
        }
    }
    /// SEBF group keys over *remaining* bytes — dynamic: the engine must
    /// re-derive a group's key (the `update_key` invalidation hook)
    /// whenever any member makes progress.
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::COFLOW]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::run;
    use crate::sim::Cluster;

    /// shuffle: two mappers send to two reducers
    fn shuffle() -> (MXDag, Vec<TaskId>) {
        let mut b = MXDag::builder();
        let m0 = b.compute("m0", 0, 1.0);
        let m1 = b.compute("m1", 1, 1.0);
        let r0 = b.compute("r0", 2, 1.0);
        let r1 = b.compute("r1", 3, 1.0);
        let f00 = b.flow("f00", 0, 2, 1.0);
        let f01 = b.flow("f01", 0, 3, 1.0);
        let f10 = b.flow("f10", 1, 2, 1.0);
        let f11 = b.flow("f11", 1, 3, 1.0);
        b.dep(m0, f00).dep(m0, f01).dep(m1, f10).dep(m1, f11);
        b.dep(f00, r0).dep(f10, r0).dep(f01, r1).dep(f11, r1);
        (b.finalize().unwrap(), vec![f00, f01, f10, f11])
    }

    #[test]
    fn by_dst_groups_aggregations() {
        let (g, flows) = shuffle();
        let s = CoflowScheduler::new(Grouping::ByDst);
        let groups = s.groups(&g);
        assert_eq!(groups.len(), 2);
        // f00,f10 -> r0 and f01,f11 -> r1
        let mut sizes: Vec<usize> = groups.iter().map(|g| g.len()).collect();
        sizes.sort();
        assert_eq!(sizes, vec![2, 2]);
        let _ = flows;
    }

    #[test]
    fn by_src_groups_broadcasts() {
        let (g, _) = shuffle();
        let s = CoflowScheduler::new(Grouping::BySrc);
        let groups = s.groups(&g);
        assert_eq!(groups.len(), 2);
    }

    #[test]
    fn by_level_one_shuffle_stage() {
        let (g, _) = shuffle();
        let s = CoflowScheduler::new(Grouping::ByLevel);
        let groups = s.groups(&g);
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0].len(), 4);
    }

    #[test]
    fn explicit_groups_pass_through() {
        let (g, flows) = shuffle();
        let s = CoflowScheduler::new(Grouping::Explicit(vec![flows.clone()]));
        assert_eq!(s.groups(&g), vec![flows]);
    }

    #[test]
    fn coflow_runs_to_completion() {
        let (g, _) = shuffle();
        for grouping in [Grouping::ByDst, Grouping::BySrc, Grouping::ByLevel] {
            let r = run(&CoflowScheduler::new(grouping), &g, &Cluster::uniform(4)).unwrap();
            assert!(r.makespan > 0.0 && r.makespan.is_finite());
        }
    }

    /// §2.2: coflow forces simultaneous completion; per-flow scheduling
    /// can finish one side earlier. With asymmetric compute after the
    /// flows, the coflow plan is strictly worse.
    #[test]
    fn coflow_obscures_critical_path() {
        // A sends f1 (then long compute) and f2 (then short compute).
        let mut b = MXDag::builder();
        let a = b.compute("A", 0, 0.5);
        let f1 = b.flow("f1", 0, 1, 1.0);
        let f2 = b.flow("f2", 0, 2, 1.0);
        let long = b.compute("long", 1, 3.0);
        let short = b.compute("short", 2, 1.0);
        b.dep(a, f1).dep(a, f2).dep(f1, long).dep(f2, short);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(3);

        let co = run(
            &CoflowScheduler::new(Grouping::Explicit(vec![vec![f1, f2]])),
            &g,
            &cluster,
        )
        .unwrap();
        let mx = run(&crate::sched::MxScheduler::default(), &g, &cluster).unwrap();
        assert!(
            mx.makespan < co.makespan - 1e-9,
            "mxdag {} should beat coflow {}",
            mx.makespan,
            co.makespan
        );
    }
}
