//! Multi-MXDAG scheduling — Principle 2 (§4.2).
//!
//! *"Let each MXDAG be altruistic by delaying its non-critical path
//! resource allocation to benefit other MXDAGs' critical paths, without
//! increasing its own end-to-end completion time."*
//!
//! Mechanism: per-job CPM; each job's non-critical tasks are gated to
//! their latest start time (LST) and demoted below every critical task,
//! so the resources they would have idly held flow to other jobs'
//! critical tasks (the CARBYNE-compatible behaviour of Fig. 7(d)).

use std::collections::BTreeMap;

use super::{Plan, Scheduler};
use crate::mxdag::{cpm, MXDag, TaskId, TaskKind};
use crate::sim::{Annotations, Cluster, Policy, QueueDiscipline, SimResult};

/// Several MXDAGs merged onto one shared cluster.
#[derive(Debug, Clone)]
pub struct MultiDag {
    /// The merged graph (single global v_S/v_E).
    pub dag: MXDag,
    /// Tasks of each job, in merged-graph ids.
    pub jobs: Vec<Vec<TaskId>>,
}

/// Merge independent job MXDAGs into one graph over the shared cluster.
pub fn merge(job_dags: &[MXDag]) -> MultiDag {
    let mut b = MXDag::builder();
    let mut jobs = Vec::with_capacity(job_dags.len());
    for jd in job_dags {
        let mut map: BTreeMap<TaskId, TaskId> = BTreeMap::new();
        let mut mine = Vec::new();
        for t in jd.tasks() {
            if t.kind.is_dummy() {
                continue;
            }
            let nid = match t.kind {
                TaskKind::Compute { host } => b.compute_full(&t.name, host, t.size, t.unit),
                TaskKind::Flow { src, dst } => b.flow_full(&t.name, src, dst, t.size, t.unit),
                _ => unreachable!(),
            };
            map.insert(t.id, nid);
            mine.push(nid);
        }
        for t in jd.tasks() {
            for &s in jd.succs(t.id) {
                if let (Some(&a), Some(&bb)) = (map.get(&t.id), map.get(&s)) {
                    b.dep(a, bb);
                }
            }
        }
        jobs.push(mine);
    }
    MultiDag { dag: b.finalize().expect("merged multi-dag must be acyclic"), jobs }
}

impl MultiDag {
    /// Job completion time: latest finish among the job's tasks.
    pub fn jct(&self, job: usize, r: &SimResult) -> f64 {
        self.jobs[job]
            .iter()
            .map(|&t| r.finish_of(t))
            .fold(0.0, f64::max)
    }
}

/// Per-job CPM restricted to the merged graph: durations of other jobs'
/// tasks are zeroed so each job sees only its own structure.
fn per_job_cpm(multi: &MultiDag, job: usize) -> crate::mxdag::Cpm {
    let mut dur: Vec<f64> = vec![0.0; multi.dag.len()];
    for &t in &multi.jobs[job] {
        dur[t] = multi.dag.task(t).size;
    }
    crate::mxdag::cpm_with(&multi.dag, &dur)
}

/// Principle-2 scheduler.
#[derive(Debug, Clone, Copy, Default)]
pub struct AltruisticScheduler;

impl AltruisticScheduler {
    /// The raw Principle-2 plan: critical tasks of any job outrank all
    /// non-critical tasks; non-critical tasks are gated to
    /// `max(EST, LST − Size)` — one task-size of margin so that even at
    /// half rate (fair sharing after the gate) the task still meets its
    /// latest finish time.
    pub fn plan_multi_raw(&self, multi: &MultiDag) -> Plan {
        let mut ann = Annotations::default();
        let n = multi.dag.len();
        for (job, tasks) in multi.jobs.iter().enumerate() {
            let c = per_job_cpm(multi, job);
            let prios = c.priorities();
            for &t in tasks {
                if c.is_critical(t) {
                    ann.priorities.insert(t, n as i64 + prios[t]);
                } else {
                    ann.priorities.insert(t, prios[t]);
                    let margin_gate =
                        (c.lst[t] - multi.dag.task(t).size).max(c.est[t]);
                    ann.gates.insert(t, margin_gate);
                }
            }
        }
        Plan { ann, policy: Policy::priority() }
    }

    /// Principle-2 plan with the paper's guarantee enforced ("without
    /// increasing its own end-to-end completion time"): the raw plan is
    /// what-if simulated against the selfish plan on `cluster`; if any
    /// job would regress, fall back to selfish.
    pub fn plan_multi_checked(
        &self,
        multi: &MultiDag,
        cluster: &crate::sim::Cluster,
    ) -> Plan {
        let altru = self.plan_multi_raw(multi);
        let selfish = SelfishScheduler.plan_multi(multi);
        let (Ok(ra), Ok(rs)) = (
            super::evaluate(&multi.dag, cluster, &altru),
            super::evaluate(&multi.dag, cluster, &selfish),
        ) else {
            return selfish;
        };
        for j in 0..multi.jobs.len() {
            if multi.jct(j, &ra) > multi.jct(j, &rs) + 1e-9 {
                return selfish; // not Pareto: honour the guarantee
            }
        }
        altru
    }

    /// Backwards-compatible alias for the raw plan.
    pub fn plan_multi(&self, multi: &MultiDag) -> Plan {
        self.plan_multi_raw(multi)
    }
}

impl Scheduler for AltruisticScheduler {
    fn name(&self) -> &'static str {
        "altruistic"
    }
    /// Single-DAG degenerate case: behaves like critical-path priority.
    fn plan(&self, dag: &MXDag, _cluster: &Cluster) -> Plan {
        let c = cpm(dag);
        let prios = c.priorities();
        let mut ann = Annotations::default();
        for t in dag.real_tasks() {
            ann.priorities.insert(t, prios[t]);
        }
        Plan { ann, policy: Policy::priority() }
    }
    /// Static priorities plus gates; the leftover-bandwidth altruism is
    /// expressed through gate times, not through drifting keys, so the
    /// queue keys themselves never go stale.
    /// [`plan_multi_checked`](AltruisticScheduler::plan_multi_checked)
    /// may fall back to the selfish fair plan when the Pareto guarantee
    /// would be violated, hence the second declared discipline.
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::PRIORITY, QueueDiscipline::FAIR]
    }
}

/// Baseline for Fig. 7(c): every job grabs resources as soon as tasks are
/// ready; critical-path priorities exist only *within* a job but nothing
/// is delayed for anyone else.
#[derive(Debug, Clone, Copy, Default)]
pub struct SelfishScheduler;

impl SelfishScheduler {
    pub fn plan_multi(&self, multi: &MultiDag) -> Plan {
        let mut ann = Annotations::default();
        for (job, tasks) in multi.jobs.iter().enumerate() {
            let c = per_job_cpm(multi, job);
            let prios = c.priorities();
            for &t in tasks {
                ann.priorities.insert(t, prios[t]);
            }
        }
        Plan { ann, policy: Policy::fair() }
    }
}

impl Scheduler for SelfishScheduler {
    fn name(&self) -> &'static str {
        "selfish"
    }
    fn plan(&self, _dag: &MXDag, _cluster: &Cluster) -> Plan {
        Plan::fair()
    }
    /// Plain fair sharing (per-job priorities exist only in the
    /// multi-DAG plan, which also uses the fair policy).
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::FAIR]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::evaluate;
    use crate::sim::Cluster;
    use crate::workloads;

    #[test]
    fn merge_preserves_structure() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1.clone(), j2.clone()]);
        assert_eq!(
            multi.dag.real_tasks().count(),
            j1.real_tasks().count() + j2.real_tasks().count()
        );
        assert_eq!(multi.jobs.len(), 2);
    }

    #[test]
    fn fig7_altruism_helps_job2_without_hurting_job1() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        let cluster = Cluster::uniform(4);

        let selfish = evaluate(
            &multi.dag,
            &cluster,
            &SelfishScheduler.plan_multi(&multi),
        )
        .unwrap();
        let altru = evaluate(
            &multi.dag,
            &cluster,
            &AltruisticScheduler.plan_multi(&multi),
        )
        .unwrap();

        let t2_selfish = multi.jct(1, &selfish);
        let t1_altru = multi.jct(1, &altru);
        assert!(
            t1_altru < t2_selfish - 1e-9,
            "job2 must improve: selfish {t2_selfish} vs altruistic {t1_altru}"
        );
        // job1 unchanged (its critical path owns its resources either way)
        let j1_selfish = multi.jct(0, &selfish);
        let j1_altru = multi.jct(0, &altru);
        assert!(
            j1_altru <= j1_selfish + 1e-9,
            "job1 must not get worse: {j1_selfish} -> {j1_altru}"
        );
    }

    #[test]
    fn per_job_cpm_ignores_other_jobs() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        let c0 = per_job_cpm(&multi, 0);
        // job 1's critical path length is its own 5.0, not inflated by job 2
        assert!((c0.makespan - 5.0).abs() < 1e-9, "got {}", c0.makespan);
    }

    #[test]
    fn single_dag_altruistic_equals_critical_priority() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 1.0);
        let f = b.flow("f", 0, 1, 1.0);
        b.dep(a, f);
        let g = b.finalize().unwrap();
        let r = crate::sched::run(&AltruisticScheduler, &g, &Cluster::uniform(2)).unwrap();
        assert!((r.makespan - 2.0).abs() < 1e-9);
    }
}
