//! Multi-MXDAG scheduling — Principle 2 (§4.2).
//!
//! *"Let each MXDAG be altruistic by delaying its non-critical path
//! resource allocation to benefit other MXDAGs' critical paths, without
//! increasing its own end-to-end completion time."*
//!
//! Mechanism: per-job CPM; each job's non-critical tasks are gated to
//! their latest start time (LST) and demoted below every critical task,
//! so the resources they would have idly held flow to other jobs'
//! critical tasks (the CARBYNE-compatible behaviour of Fig. 7(d)).

use std::collections::BTreeMap;

use super::mxsched::{cpm_durations, cpm_on};
use super::{Plan, Scheduler};
use crate::mxdag::{MXDag, TaskId, TaskKind};
use crate::sim::{Annotations, Cluster, Policy, QueueDiscipline, SimResult};

/// Several MXDAGs merged onto one shared cluster.
#[derive(Debug, Clone)]
pub struct MultiDag {
    /// The merged graph (single global v_S/v_E).
    pub dag: MXDag,
    /// Tasks of each job, in merged-graph ids.
    pub jobs: Vec<Vec<TaskId>>,
}

/// Merge independent job MXDAGs into one graph over the shared cluster.
pub fn merge(job_dags: &[MXDag]) -> MultiDag {
    let mut b = MXDag::builder();
    let mut jobs = Vec::with_capacity(job_dags.len());
    for jd in job_dags {
        let mut map: BTreeMap<TaskId, TaskId> = BTreeMap::new();
        let mut mine = Vec::new();
        for t in jd.tasks() {
            if t.kind.is_dummy() {
                continue;
            }
            let nid = match t.kind {
                TaskKind::Compute { host } => b.compute_full(&t.name, host, t.size, t.unit),
                TaskKind::Flow { src, dst } => b.flow_full(&t.name, src, dst, t.size, t.unit),
                _ => unreachable!(),
            };
            map.insert(t.id, nid);
            mine.push(nid);
        }
        for t in jd.tasks() {
            for &s in jd.succs(t.id) {
                if let (Some(&a), Some(&bb)) = (map.get(&t.id), map.get(&s)) {
                    b.dep(a, bb);
                }
            }
        }
        jobs.push(mine);
    }
    MultiDag { dag: b.finalize().expect("merged multi-dag must be acyclic"), jobs }
}

impl MultiDag {
    /// Job completion time: latest finish among the job's tasks.
    pub fn jct(&self, job: usize, r: &SimResult) -> f64 {
        self.jobs[job]
            .iter()
            .map(|&t| r.finish_of(t))
            .fold(0.0, f64::max)
    }
}

/// Per-job CPM restricted to the merged graph: durations of other jobs'
/// tasks are zeroed so each job sees only its own structure. `costed`
/// supplies the full-graph per-task durations — plain sizes for the
/// historical size-based spelling, or [`cpm_durations`] when the gates
/// should reason about the cluster's real per-path bandwidths.
fn per_job_cpm(multi: &MultiDag, job: usize, costed: &[f64]) -> crate::mxdag::Cpm {
    let mut dur: Vec<f64> = vec![0.0; multi.dag.len()];
    for &t in &multi.jobs[job] {
        dur[t] = costed[t];
    }
    crate::mxdag::cpm_with(&multi.dag, &dur)
}

/// Plain task sizes as durations (the unit-rate assumption).
fn size_durations(multi: &MultiDag) -> Vec<f64> {
    multi.dag.tasks().iter().map(|t| t.size).collect()
}

/// Per-job strict-priority tiers from per-tenant weights: jobs sharing
/// a weight share a tier, higher weight = higher tier. Used by the
/// weighted multi-job planners for the open-loop's multi-tenant mixes.
fn weight_tiers(weights: &[i64]) -> Vec<usize> {
    let mut distinct: Vec<i64> = weights.to_vec();
    distinct.sort_unstable();
    distinct.dedup();
    weights
        .iter()
        .map(|w| distinct.binary_search(w).expect("weight must be present"))
        .collect()
}

/// Principle-2 scheduler.
#[derive(Debug, Clone, Copy, Default)]
pub struct AltruisticScheduler;

impl AltruisticScheduler {
    /// The raw Principle-2 plan: critical tasks of any job outrank all
    /// non-critical tasks; non-critical tasks are gated to
    /// `max(EST, LST − Size)` — one task-size of margin so that even at
    /// half rate (fair sharing after the gate) the task still meets its
    /// latest finish time.
    pub fn plan_multi_raw(&self, multi: &MultiDag) -> Plan {
        self.plan_with_durations(multi, &size_durations(multi))
    }

    /// The Principle-2 plan gated by *cluster-costed* durations: per-job
    /// CPM runs over `size / solo-bottleneck-rate` ([`cpm_durations`]),
    /// so a flow squeezed through an oversubscribed or degraded fabric
    /// link carries its real duration into the LST computation. The
    /// gates — and hence how long a non-critical task may altruistically
    /// wait — then reason about fabric links, not just the unit-NIC
    /// assumption. On a uniform big-switch cluster every solo rate is 1
    /// and this is bit-identical to
    /// [`plan_multi_raw`](AltruisticScheduler::plan_multi_raw).
    pub fn plan_multi_on(&self, multi: &MultiDag, cluster: &Cluster) -> Plan {
        self.plan_with_durations(multi, &cpm_durations(&multi.dag, cluster))
    }

    /// Shared body of the raw/cluster-costed plans: critical tasks of
    /// any job outrank all non-critical tasks; non-critical tasks are
    /// gated to `max(EST, LST − duration)` in whatever duration domain
    /// `costed` expresses.
    fn plan_with_durations(&self, multi: &MultiDag, costed: &[f64]) -> Plan {
        self.plan_with_durations_tiered(multi, costed, None)
    }

    /// As [`plan_with_durations`](Self::plan_with_durations), with an
    /// optional per-job strict-priority tier: priorities within a tier
    /// keep the Principle-2 band structure (critical over non-critical
    /// across the tier's jobs), and every task of a higher tier
    /// outranks every task of a lower one. Gates are tier-independent.
    /// `None` (and uniform tiers) reproduce the unweighted plan
    /// bit-for-bit.
    fn plan_with_durations_tiered(
        &self,
        multi: &MultiDag,
        costed: &[f64],
        tiers: Option<&[usize]>,
    ) -> Plan {
        let mut ann = Annotations::default();
        let n = multi.dag.len();
        // Base priorities span [0, 2n]; one tier step clears the band.
        let stride = 2 * n as i64 + 1;
        for (job, tasks) in multi.jobs.iter().enumerate() {
            let lift = tiers.map_or(0, |t| t[job] as i64 * stride);
            let c = per_job_cpm(multi, job, costed);
            let prios = c.priorities();
            for &t in tasks {
                ann.jobs.insert(t, job);
                if c.is_critical(t) {
                    ann.priorities.insert(t, lift + n as i64 + prios[t]);
                } else {
                    ann.priorities.insert(t, lift + prios[t]);
                    let margin_gate = (c.lst[t] - costed[t]).max(c.est[t]);
                    ann.gates.insert(t, margin_gate);
                }
            }
        }
        Plan { ann, policy: Policy::priority() }
    }

    /// Per-tenant weighted Principle-2 plan for the open-loop's
    /// multi-tenant mixes: jobs with equal weight share a tier in which
    /// the usual altruistic band structure holds; a heavier tenant's
    /// tasks strictly outrank a lighter tenant's. With all weights
    /// equal this delegates to
    /// [`plan_multi_on`](AltruisticScheduler::plan_multi_on) — the
    /// unweighted path, bit-identical.
    pub fn plan_multi_weighted_on(
        &self,
        multi: &MultiDag,
        cluster: &Cluster,
        weights: &[i64],
    ) -> Plan {
        assert_eq!(weights.len(), multi.jobs.len(), "one weight per job");
        if weights.windows(2).all(|w| w[0] == w[1]) {
            return self.plan_multi_on(multi, cluster);
        }
        let tiers = weight_tiers(weights);
        self.plan_with_durations_tiered(multi, &cpm_durations(&multi.dag, cluster), Some(&tiers))
    }

    /// Principle-2 plan with the paper's guarantee enforced ("without
    /// increasing its own end-to-end completion time"): the
    /// cluster-costed plan
    /// ([`plan_multi_on`](AltruisticScheduler::plan_multi_on)) is
    /// what-if simulated
    /// against the selfish plan on `cluster`; if any job would regress,
    /// fall back to selfish.
    pub fn plan_multi_checked(
        &self,
        multi: &MultiDag,
        cluster: &crate::sim::Cluster,
    ) -> Plan {
        let altru = self.plan_multi_on(multi, cluster);
        let selfish = SelfishScheduler.plan_multi(multi);
        let (Ok(ra), Ok(rs)) = (
            super::evaluate(&multi.dag, cluster, &altru),
            super::evaluate(&multi.dag, cluster, &selfish),
        ) else {
            return selfish;
        };
        for j in 0..multi.jobs.len() {
            if multi.jct(j, &ra) > multi.jct(j, &rs) + 1e-9 {
                return selfish; // not Pareto: honour the guarantee
            }
        }
        altru
    }

    /// Backwards-compatible alias for the raw plan.
    pub fn plan_multi(&self, multi: &MultiDag) -> Plan {
        self.plan_multi_raw(multi)
    }
}

impl Scheduler for AltruisticScheduler {
    fn name(&self) -> &'static str {
        "altruistic"
    }
    /// Single-DAG degenerate case: behaves like critical-path priority,
    /// costed against the cluster ([`cpm_on`]) so a degraded or
    /// oversubscribed link reshapes criticality exactly as in the
    /// multi-job gates.
    fn plan(&self, dag: &MXDag, cluster: &Cluster) -> Plan {
        let c = cpm_on(dag, cluster);
        let prios = c.priorities();
        let mut ann = Annotations::default();
        for t in dag.real_tasks() {
            ann.priorities.insert(t, prios[t]);
        }
        Plan { ann, policy: Policy::priority() }
    }

    /// Reactive replanning after cluster churn: the whole pipeline —
    /// per-path costing, per-job CPM, LST gates — is a pure function of
    /// `(dag, cluster)`, so reacting to a degraded fabric is simply
    /// re-running it against the *current* capacities. The previous
    /// plan's gates are in stale time units and are deliberately
    /// discarded.
    fn replan(&self, dag: &MXDag, cluster: &Cluster, _previous: &Plan) -> Plan {
        self.plan(dag, cluster)
    }
    /// Static priorities plus gates; the leftover-bandwidth altruism is
    /// expressed through gate times, not through drifting keys, so the
    /// queue keys themselves never go stale.
    /// [`plan_multi_checked`](AltruisticScheduler::plan_multi_checked)
    /// may fall back to the selfish fair plan when the Pareto guarantee
    /// would be violated, hence the second declared discipline.
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::PRIORITY, QueueDiscipline::FAIR]
    }
}

/// Baseline for Fig. 7(c): every job grabs resources as soon as tasks are
/// ready; critical-path priorities exist only *within* a job but nothing
/// is delayed for anyone else.
#[derive(Debug, Clone, Copy, Default)]
pub struct SelfishScheduler;

impl SelfishScheduler {
    pub fn plan_multi(&self, multi: &MultiDag) -> Plan {
        let sizes = size_durations(multi);
        let mut ann = Annotations::default();
        for (job, tasks) in multi.jobs.iter().enumerate() {
            let c = per_job_cpm(multi, job, &sizes);
            let prios = c.priorities();
            for &t in tasks {
                ann.jobs.insert(t, job);
                ann.priorities.insert(t, prios[t]);
            }
        }
        Plan { ann, policy: Policy::fair() }
    }

    /// Per-tenant weighted fair-path plan. The engine's fair policy is
    /// unweighted, so unequal weights necessarily switch the plan to
    /// the priority discipline: tenants are served in strict weight
    /// tiers (heavier first), per-job critical-path priorities ordering
    /// tasks within a tier — fair sharing still applies among
    /// equal-priority tasks. With all weights equal this delegates to
    /// [`plan_multi`](SelfishScheduler::plan_multi), keeping the plain
    /// fair path bit-identical.
    pub fn plan_multi_weighted(&self, multi: &MultiDag, weights: &[i64]) -> Plan {
        assert_eq!(weights.len(), multi.jobs.len(), "one weight per job");
        if weights.windows(2).all(|w| w[0] == w[1]) {
            return self.plan_multi(multi);
        }
        let tiers = weight_tiers(weights);
        let sizes = size_durations(multi);
        let n = multi.dag.len();
        let stride = n as i64 + 1; // base priorities span [0, n]
        let mut ann = Annotations::default();
        for (job, tasks) in multi.jobs.iter().enumerate() {
            let lift = tiers[job] as i64 * stride;
            let c = per_job_cpm(multi, job, &sizes);
            let prios = c.priorities();
            for &t in tasks {
                ann.jobs.insert(t, job);
                ann.priorities.insert(t, lift + prios[t]);
            }
        }
        Plan { ann, policy: Policy::priority() }
    }
}

impl Scheduler for SelfishScheduler {
    fn name(&self) -> &'static str {
        "selfish"
    }
    fn plan(&self, _dag: &MXDag, _cluster: &Cluster) -> Plan {
        Plan::fair()
    }
    /// Plain fair sharing (per-job priorities exist only in the
    /// multi-DAG plan, which also uses the fair policy).
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::FAIR]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::evaluate;
    use crate::sim::Cluster;
    use crate::workloads;

    #[test]
    fn merge_preserves_structure() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1.clone(), j2.clone()]);
        assert_eq!(
            multi.dag.real_tasks().count(),
            j1.real_tasks().count() + j2.real_tasks().count()
        );
        assert_eq!(multi.jobs.len(), 2);
    }

    #[test]
    fn fig7_altruism_helps_job2_without_hurting_job1() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        let cluster = Cluster::uniform(4);

        let selfish = evaluate(
            &multi.dag,
            &cluster,
            &SelfishScheduler.plan_multi(&multi),
        )
        .unwrap();
        let altru = evaluate(
            &multi.dag,
            &cluster,
            &AltruisticScheduler.plan_multi(&multi),
        )
        .unwrap();

        let t2_selfish = multi.jct(1, &selfish);
        let t1_altru = multi.jct(1, &altru);
        assert!(
            t1_altru < t2_selfish - 1e-9,
            "job2 must improve: selfish {t2_selfish} vs altruistic {t1_altru}"
        );
        // job1 unchanged (its critical path owns its resources either way)
        let j1_selfish = multi.jct(0, &selfish);
        let j1_altru = multi.jct(0, &altru);
        assert!(
            j1_altru <= j1_selfish + 1e-9,
            "job1 must not get worse: {j1_selfish} -> {j1_altru}"
        );
    }

    #[test]
    fn per_job_cpm_ignores_other_jobs() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        let c0 = per_job_cpm(&multi, 0, &size_durations(&multi));
        // job 1's critical path length is its own 5.0, not inflated by job 2
        assert!((c0.makespan - 5.0).abs() < 1e-9, "got {}", c0.makespan);
    }

    /// On a uniform big-switch cluster every solo rate is 1, so the
    /// cluster-costed plan must be bit-identical to the size-based one
    /// (this is what keeps `plan_multi_checked`'s switch to
    /// [`AltruisticScheduler::plan_multi_on`] invisible on the Fig. 7
    /// scenarios).
    #[test]
    fn plan_multi_on_uniform_matches_size_based() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        let raw = AltruisticScheduler.plan_multi_raw(&multi);
        let on = AltruisticScheduler.plan_multi_on(&multi, &Cluster::uniform(4));
        assert_eq!(raw.ann.priorities, on.ann.priorities);
        assert_eq!(raw.ann.gates.len(), on.ann.gates.len());
        for (t, g) in &raw.ann.gates {
            assert_eq!(g.to_bits(), on.ann.gates[t].to_bits(), "gate of task {t}");
        }
    }

    /// Principle-2 gating must reason about oversubscribed fabric links:
    /// a size-2 cross-rack flow really takes 4 through a 0.5-capacity
    /// aggregation link, so its latest start collapses from 4 to 2 and
    /// the one-duration altruism margin swallows the whole gate. The
    /// size-based spelling would happily hold it back until t = 2.
    #[test]
    fn fabric_costing_tightens_altruistic_gates() {
        let mut b = MXDag::builder();
        let fa = b.flow("fa", 2, 3, 6.0); // intra-rack: solo rate 1
        let fb = b.flow("fb", 0, 2, 2.0); // cross-rack: solo rate 0.5
        let _ = fa;
        let g = b.finalize().unwrap();
        let multi = merge(&[g]);
        let fb = multi.dag.by_name("fb").unwrap();

        // size-based: critical path 6, fb LST 4, gate max(0, 4-2) = 2
        let raw = AltruisticScheduler.plan_multi_raw(&multi);
        assert!((raw.ann.gates[&fb] - 2.0).abs() < 1e-9, "size-based gate {:?}", raw.ann.gates);

        // costed on agg cap 0.5: fb duration 4, LST 2, gate max(0, 2-4) = 0
        let oversub = Cluster::oversubscribed(4, 2, 4.0);
        let on = AltruisticScheduler.plan_multi_on(&multi, &oversub);
        assert!((on.ann.gates[&fb] - 0.0).abs() < 1e-9, "costed gate {:?}", on.ann.gates);
    }

    /// Both multi-DAG planners stamp every task with its job index —
    /// the quarantine unit the recovery layer keys on — and the map
    /// survives expansion into the physical DAG.
    #[test]
    fn multi_plans_carry_the_job_map() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        for plan in [
            AltruisticScheduler.plan_multi(&multi),
            SelfishScheduler.plan_multi(&multi),
        ] {
            for (job, tasks) in multi.jobs.iter().enumerate() {
                for t in tasks {
                    assert_eq!(plan.ann.jobs.get(t), Some(&job), "task {t} of job {job}");
                }
            }
            let sim = crate::sim::expand(&multi.dag, &plan.ann);
            assert_eq!(sim.n_jobs(), 2);
        }
    }

    #[test]
    fn equal_weights_are_bit_identical_to_unweighted() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        let cluster = Cluster::uniform(4);

        let flat = SelfishScheduler.plan_multi(&multi);
        let w = SelfishScheduler.plan_multi_weighted(&multi, &[3, 3]);
        assert_eq!(flat.policy, w.policy);
        assert_eq!(flat.ann.priorities, w.ann.priorities);

        let flat = AltruisticScheduler.plan_multi_on(&multi, &cluster);
        let w = AltruisticScheduler.plan_multi_weighted_on(&multi, &cluster, &[3, 3]);
        assert_eq!(flat.policy, w.policy);
        assert_eq!(flat.ann.priorities, w.ann.priorities);
        assert_eq!(flat.ann.gates.len(), w.ann.gates.len());
        for (t, g) in &flat.ann.gates {
            assert_eq!(g.to_bits(), w.ann.gates[t].to_bits(), "gate of task {t}");
        }
    }

    #[test]
    fn heavier_tenant_outranks_lighter_everywhere() {
        let (j1, j2) = workloads::fig7_jobs();
        let multi = merge(&[j1, j2]);
        let cluster = Cluster::uniform(4);

        // Selfish path: weighting switches to the priority discipline.
        let w = SelfishScheduler.plan_multi_weighted(&multi, &[1, 5]);
        assert_eq!(w.policy, Policy::priority());
        let min_heavy = multi.jobs[1].iter().map(|t| w.ann.priorities[t]).min().unwrap();
        let max_light = multi.jobs[0].iter().map(|t| w.ann.priorities[t]).max().unwrap();
        assert!(min_heavy > max_light, "tier dominance: {min_heavy} vs {max_light}");

        // Altruistic path: same dominance; gates don't depend on tiers.
        let w = AltruisticScheduler.plan_multi_weighted_on(&multi, &cluster, &[1, 5]);
        let flat = AltruisticScheduler.plan_multi_on(&multi, &cluster);
        let min_heavy = multi.jobs[1].iter().map(|t| w.ann.priorities[t]).min().unwrap();
        let max_light = multi.jobs[0].iter().map(|t| w.ann.priorities[t]).max().unwrap();
        assert!(min_heavy > max_light, "tier dominance: {min_heavy} vs {max_light}");
        for (t, g) in &flat.ann.gates {
            assert_eq!(g.to_bits(), w.ann.gates[t].to_bits(), "gate of task {t}");
        }

        // Equal-weight jobs share a tier in input order of bands.
        let tiers = super::weight_tiers(&[5, 1, 5, 2]);
        assert_eq!(tiers, vec![2, 0, 2, 1]);
    }

    #[test]
    fn single_dag_altruistic_equals_critical_priority() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 1.0);
        let f = b.flow("f", 0, 1, 1.0);
        b.dep(a, f);
        let g = b.finalize().unwrap();
        let r = crate::sched::run(&AltruisticScheduler, &g, &Cluster::uniform(2)).unwrap();
        assert!((r.makespan - 2.0).abs() < 1e-9);
    }
}
