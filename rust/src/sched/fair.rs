//! Network-aware fair-share baseline (§2.1, Fig. 1(b)).
//!
//! Models the behaviour of network-aware DAG schedulers (Graphene,
//! Tetris): bandwidth is a divisible resource shared max-min fairly, but
//! there is *no explicit flow-level scheduling* — no priorities, no
//! gating, no pipelining decisions.

use super::{Plan, Scheduler};
use crate::mxdag::MXDag;
use crate::sim::{Cluster, QueueDiscipline};

/// The fair-sharing baseline scheduler (empty plan, max-min policy).
#[derive(Debug, Clone, Copy, Default)]
pub struct FairScheduler;

impl Scheduler for FairScheduler {
    fn name(&self) -> &'static str {
        "fair"
    }
    fn plan(&self, _dag: &MXDag, _cluster: &Cluster) -> Plan {
        Plan::fair()
    }
    /// Single shared ready-queue level for both classes; keys never go
    /// stale.
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::FAIR]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::run;
    use crate::sim::Cluster;

    /// Fig. 1(b): two unit flows out of host A share the NIC fairly and
    /// both finish at t=2 — delaying the downstream task.
    #[test]
    fn fig1b_fair_sharing_delays_downstream() {
        let mut b = MXDag::builder();
        let f1 = b.flow("f1", 0, 1, 1.0);
        let f3 = b.flow("f3", 0, 2, 1.0);
        let c = b.compute("c", 1, 1.0);
        b.dep(f1, c);
        let _ = f3;
        let g = b.finalize().unwrap();
        let r = run(&FairScheduler, &g, &Cluster::uniform(3)).unwrap();
        // f1 shares with f3 -> finishes at 2 -> c at 3
        assert!((r.finish_of(g.by_name("c").unwrap()) - 3.0).abs() < 1e-9);
    }
}
