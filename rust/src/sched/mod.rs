//! Schedulers over MXDAGs.
//!
//! The MXDAG co-scheduler (`MxScheduler`, Principle 1; `AltruisticScheduler`,
//! Principle 2) and the baselines the paper argues against:
//! network-aware fair sharing, plain-DAG FIFO, Varys-style coflow with
//! pluggable grouping (the Fig. 2(b1..b3) ambiguity), and a Tetris-like
//! packing heuristic.
//!
//! ## The scheduler ↔ engine contract
//!
//! A scheduler never touches the event loop: it maps `(MXDag, Cluster)`
//! to a [`Plan`] — per-task annotations (priorities, gates, pipelining,
//! coflow groups) plus a [`Policy`] naming the sharing semantics. The
//! engine serves that plan from an incremental ready queue
//! ([`crate::sim::ReadyQueue`]): every ready task carries a priority
//! key derived from the plan, and the engine walks key levels high → low
//! at each event. The contract has two sides:
//!
//! * [`Scheduler::plan`] produces the annotations the keys are derived
//!   from;
//! * [`Scheduler::disciplines`] declares which
//!   [`QueueDiscipline`]s (key shapes + invalidation behaviour) the
//!   scheduler's plans may request. Every emitted plan must satisfy
//!   `disciplines().contains(&plan.policy.discipline())` — checked by
//!   the `declared_disciplines_cover_emitted_plans` test below.
//!
//! Disciplines with *dynamic* keys (coflow SEBF, whose bounds shrink
//! with remaining bytes) additionally rely on the engine invoking the
//! [`update_key`](crate::sim::ReadyQueue::update_key) invalidation hook
//! after every progress step; a scheduler introducing a new
//! drifting-priority policy must extend
//! [`Keying`](crate::sim::Keying) so the engine knows to do the same.
//! `docs/ARCHITECTURE.md` walks through the whole lifecycle.

pub mod altruistic;
pub mod coflow;
pub mod context;
pub mod fair;
pub mod fifo;
pub mod mxsched;
pub mod packing;

use crate::mxdag::MXDag;
use crate::sim::{
    expand, simulate, Annotations, Cluster, DynAction, DynTimeline, LinkRef, Policy,
    QueueDiscipline, SimConfig, SimError, SimResult,
};

pub use altruistic::{AltruisticScheduler, SelfishScheduler};
pub use coflow::{CoflowScheduler, Grouping};
pub use context::EvalContext;
pub use fair::FairScheduler;
pub use fifo::FifoScheduler;
pub use mxsched::MxScheduler;
pub use packing::PackingScheduler;

/// A concrete schedule: per-task annotations + a sharing policy.
#[derive(Debug, Clone)]
pub struct Plan {
    /// Per-task priorities, gates, pipelining and coflow groups, applied
    /// during DAG expansion ([`expand`]).
    pub ann: Annotations,
    /// The sharing semantics the engine enforces (and, via
    /// [`Policy::discipline`], how ready tasks are keyed).
    pub policy: Policy,
}

impl Plan {
    /// The empty fair-sharing plan (no annotations).
    pub fn fair() -> Plan {
        Plan { ann: Annotations::default(), policy: Policy::fair() }
    }
}

/// A scheduler maps (MXDAG, cluster) to a [`Plan`].
pub trait Scheduler {
    /// Short stable name (bench tables, CLI `--scheduler`).
    fn name(&self) -> &'static str;

    /// Produce the schedule for `dag` on `cluster`.
    fn plan(&self, dag: &MXDag, cluster: &Cluster) -> Plan;

    /// React to a cluster change mid-run: produce a fresh schedule for
    /// the (possibly degraded) `cluster`, given the plan that was in
    /// force before the change. The default simply re-plans from
    /// scratch — correct for every scheduler whose `plan` is a pure
    /// function of `(dag, cluster)`. Schedulers that cost paths through
    /// the cluster (`MxScheduler`'s Eq. 2 ordering, the altruistic
    /// CPM gates) override this to document that the re-run sees the
    /// *degraded* capacities, so Principle-2 gating reasons about
    /// oversubscribed fabric links rather than the nominal NIC rates.
    fn replan(&self, dag: &MXDag, cluster: &Cluster, _previous: &Plan) -> Plan {
        self.plan(dag, cluster)
    }

    /// The ready-queue disciplines this scheduler's plans may request
    /// from the engine (see the module docs). Most schedulers emit a
    /// single discipline; `MxScheduler` may also fall back to fair
    /// sharing when its priority plan loses the what-if comparison.
    fn disciplines(&self) -> &'static [QueueDiscipline];
}

/// Expand + simulate a plan. The single evaluation entry point used by
/// benches, what-if analysis and the pipeline search.
pub fn evaluate(dag: &MXDag, cluster: &Cluster, plan: &Plan) -> Result<SimResult, SimError> {
    evaluate_with(dag, cluster, plan, &SimConfig::default())
}

/// As [`evaluate`], but with explicit engine configuration (queue kind,
/// allocation kind, horizon kind, event budget) — the hook the CLI's
/// `--queue` / `--alloc` / `--horizon` flags and the scenario-JSON
/// `"engine"` object plug into. `cfg.policy` is overridden by the
/// plan's policy — a plan's annotations and its sharing semantics are
/// inseparable.
pub fn evaluate_with(
    dag: &MXDag,
    cluster: &Cluster,
    plan: &Plan,
    cfg: &SimConfig,
) -> Result<SimResult, SimError> {
    let sim = expand(dag, &plan.ann);
    simulate(&sim, cluster, &SimConfig { policy: plan.policy, ..cfg.clone() })
}

/// Convenience: schedule with `s` and return the simulated result.
pub fn run(s: &dyn Scheduler, dag: &MXDag, cluster: &Cluster) -> Result<SimResult, SimError> {
    evaluate(dag, cluster, &s.plan(dag, cluster))
}

/// The cluster after every event in `tl` has fired: host-level factors
/// (`SlowHost`, `FailHost`, `RestoreHost`) and per-host-slot link
/// factors (`Degrade`/`Restore` on `core:`/`up:`/`down:` links) are
/// folded, in timeline order, into the host capacities. Factors are
/// absolute (last writer wins), mirroring `DynState`. Fabric-extra
/// factors (aggregation uplinks, parallel-fabric trunks) have no slot
/// in [`Cluster`]'s host list and are ignored — a replan against the
/// settled cluster sees degraded *hosts* exactly, degraded *fabric*
/// only through whatever the topology already encodes.
pub fn settled_cluster(cluster: &Cluster, tl: &DynTimeline) -> Cluster {
    let n = cluster.hosts.len();
    let mut host_f = vec![1.0f64; n];
    // Per-slot link factors in arena order: [core, up, down] per host.
    let mut link_f = vec![1.0f64; 3 * n];
    let slot_of = |link: LinkRef| -> Option<usize> {
        match link {
            LinkRef::Core(h) if h < n => Some(3 * h),
            LinkRef::NicUp(h) if h < n => Some(3 * h + 1),
            LinkRef::NicDown(h) if h < n => Some(3 * h + 2),
            _ => None,
        }
    };
    for e in tl.events() {
        match e.action {
            DynAction::Degrade { link, factor } => {
                if let Some(r) = slot_of(link) {
                    link_f[r] = factor;
                }
            }
            DynAction::Restore { link } => {
                if let Some(r) = slot_of(link) {
                    link_f[r] = 1.0;
                }
            }
            DynAction::SlowHost { host, factor } if host < n => host_f[host] = factor,
            DynAction::RestoreHost { host } if host < n => host_f[host] = 1.0,
            DynAction::FailHost { host } if host < n => host_f[host] = 0.0,
            _ => {}
        }
    }
    let mut out = cluster.clone();
    for (h, host) in out.hosts.iter_mut().enumerate() {
        host.cores *= host_f[h] * link_f[3 * h];
        host.nic_up *= host_f[h] * link_f[3 * h + 1];
        host.nic_down *= host_f[h] * link_f[3 * h + 2];
    }
    out
}

/// Evaluate `plan` under `cfg`, then — when the run shows the cluster
/// changed out from under the plan — ask the scheduler for a reactive
/// replan against the [`settled_cluster`]. The replan fires when any
/// job finished non-[`Completed`](crate::sim::JobOutcome::Completed)
/// (quarantine / retry exhaustion) **or** the timeline contains a
/// [`DynAction::FailHost`]: either way the capacities the original
/// plan was costed against are gone, so `MxScheduler`'s Eq. 2 ordering
/// and the altruistic CPM gates should re-cost the surviving work.
/// Returns the first run's result plus the fresh plan (if one fired);
/// the caller decides what to do with it (re-evaluate, diff, ship).
pub fn evaluate_reactive(
    s: &dyn Scheduler,
    dag: &MXDag,
    cluster: &Cluster,
    plan: &Plan,
    cfg: &SimConfig,
) -> Result<(SimResult, Option<Plan>), SimError> {
    let result = evaluate_with(dag, cluster, plan, cfg)?;
    let crashed = cfg
        .dynamics
        .events()
        .iter()
        .any(|e| matches!(e.action, DynAction::FailHost { .. }));
    let degraded = crashed || result.jobs.iter().any(|j| !j.is_completed());
    let fresh = if degraded {
        Some(s.replan(dag, &settled_cluster(cluster, &cfg.dynamics), plan))
    } else {
        None
    };
    Ok((result, fresh))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::MXDag;
    use crate::workloads::{random_dag, RandomParams};

    #[test]
    fn evaluate_fair_plan() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 2.0);
        let f = b.flow("f", 0, 1, 1.0);
        b.dep(a, f);
        let g = b.finalize().unwrap();
        let r = evaluate(&g, &Cluster::uniform(2), &Plan::fair()).unwrap();
        assert!((r.makespan - 3.0).abs() < 1e-9);
    }

    #[test]
    fn run_uses_scheduler_plan() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 1.0);
        let _ = a;
        let g = b.finalize().unwrap();
        let r = run(&FairScheduler, &g, &Cluster::uniform(1)).unwrap();
        assert!((r.makespan - 1.0).abs() < 1e-9);
    }

    #[test]
    fn settled_cluster_folds_terminal_host_factors() {
        use crate::sim::{DynAction, DynTimeline, LinkRef};
        let cluster = Cluster::uniform(3);
        let tl = DynTimeline::new()
            .with(1.0, DynAction::SlowHost { host: 0, factor: 0.5 })
            .with(2.0, DynAction::FailHost { host: 1 })
            .with(3.0, DynAction::SlowHost { host: 0, factor: 0.25 })
            .with(4.0, DynAction::Degrade { link: LinkRef::NicUp(2), factor: 0.1 })
            .with(5.0, DynAction::RestoreHost { host: 1 });
        let c = settled_cluster(&cluster, &tl);
        // Host 0: last writer 0.25 on all three slots.
        assert!((c.hosts[0].cores - 0.25).abs() < 1e-12);
        assert!((c.hosts[0].nic_down - 0.25).abs() < 1e-12);
        // Host 1: crashed then restored — back to nominal.
        assert!((c.hosts[1].cores - 1.0).abs() < 1e-12);
        // Host 2: only the uplink degraded.
        assert!((c.hosts[2].nic_up - 0.1).abs() < 1e-12);
        assert!((c.hosts[2].cores - 1.0).abs() < 1e-12);
        assert!((c.hosts[2].nic_down - 1.0).abs() < 1e-12);
    }

    #[test]
    fn evaluate_reactive_replans_on_host_failure() {
        use crate::sim::{DynAction, DynTimeline, RecoveryPolicy};
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 2.0);
        let c = b.compute("c", 1, 2.0);
        let f = b.flow("f", 0, 1, 1.0);
        b.dep(a, f);
        let _ = c;
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let s = MxScheduler::without_pipelining();
        let plan = s.plan(&g, &cluster);

        // Quiet cluster: no replan fires.
        let (_, fresh) =
            evaluate_reactive(&s, &g, &cluster, &plan, &SimConfig::default()).unwrap();
        assert!(fresh.is_none());

        // A crash after everything on the host finished: job completes,
        // but the FailHost alone is reason enough to re-cost.
        let cfg = SimConfig {
            dynamics: DynTimeline::new().with(100.0, DynAction::FailHost { host: 1 }),
            recovery: RecoveryPolicy::retry_default(),
            ..SimConfig::default()
        };
        let (r, fresh) = evaluate_reactive(&s, &g, &cluster, &plan, &cfg).unwrap();
        assert!(r.jobs.iter().all(|j| j.is_completed()));
        let fresh = fresh.expect("FailHost must trigger a replan");
        // The replan saw the settled (host-1-dead) cluster and is a
        // usable plan: it still declares a covered discipline.
        assert!(s.disciplines().contains(&fresh.policy.discipline()));
    }

    /// The contract: every plan a scheduler emits must use one of its
    /// declared queue disciplines.
    #[test]
    fn declared_disciplines_cover_emitted_plans() {
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FairScheduler),
            Box::new(FifoScheduler),
            Box::new(PackingScheduler),
            Box::new(CoflowScheduler::new(Grouping::ByDst)),
            Box::new(MxScheduler::without_pipelining()),
            Box::new(AltruisticScheduler),
            Box::new(SelfishScheduler),
        ];
        for seed in [1u64, 5, 9] {
            let p = RandomParams { seed, ..Default::default() };
            let g = random_dag(&p);
            let cluster = Cluster::uniform(p.hosts);
            for s in &schedulers {
                let plan = s.plan(&g, &cluster);
                assert!(
                    s.disciplines().contains(&plan.policy.discipline()),
                    "{} emitted undeclared discipline {:?} (declares {:?})",
                    s.name(),
                    plan.policy.discipline(),
                    s.disciplines(),
                );
            }
        }
    }
}
