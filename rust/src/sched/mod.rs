//! Schedulers over MXDAGs.
//!
//! The MXDAG co-scheduler (`MxScheduler`, Principle 1; `AltruisticScheduler`,
//! Principle 2) and the baselines the paper argues against:
//! network-aware fair sharing, plain-DAG FIFO, Varys-style coflow with
//! pluggable grouping (the Fig. 2(b1..b3) ambiguity), and a Tetris-like
//! packing heuristic.

pub mod altruistic;
pub mod coflow;
pub mod fair;
pub mod fifo;
pub mod mxsched;
pub mod packing;

use crate::mxdag::MXDag;
use crate::sim::{
    expand, simulate, Annotations, Cluster, Policy, SimConfig, SimError, SimResult,
};

pub use altruistic::{AltruisticScheduler, SelfishScheduler};
pub use coflow::{CoflowScheduler, Grouping};
pub use fair::FairScheduler;
pub use fifo::FifoScheduler;
pub use mxsched::MxScheduler;
pub use packing::PackingScheduler;

/// A concrete schedule: per-task annotations + a sharing policy.
#[derive(Debug, Clone)]
pub struct Plan {
    pub ann: Annotations,
    pub policy: Policy,
}

impl Plan {
    pub fn fair() -> Plan {
        Plan { ann: Annotations::default(), policy: Policy::fair() }
    }
}

/// A scheduler maps (MXDAG, cluster) to a Plan.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    fn plan(&self, dag: &MXDag, cluster: &Cluster) -> Plan;
}

/// Expand + simulate a plan. The single evaluation entry point used by
/// benches, what-if analysis and the pipeline search.
pub fn evaluate(dag: &MXDag, cluster: &Cluster, plan: &Plan) -> Result<SimResult, SimError> {
    let sim = expand(dag, &plan.ann);
    simulate(&sim, cluster, &SimConfig { policy: plan.policy, ..Default::default() })
}

/// Convenience: schedule with `s` and return the simulated result.
pub fn run(s: &dyn Scheduler, dag: &MXDag, cluster: &Cluster) -> Result<SimResult, SimError> {
    evaluate(dag, cluster, &s.plan(dag, cluster))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mxdag::MXDag;

    #[test]
    fn evaluate_fair_plan() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 2.0);
        let f = b.flow("f", 0, 1, 1.0);
        b.dep(a, f);
        let g = b.finalize().unwrap();
        let r = evaluate(&g, &Cluster::uniform(2), &Plan::fair()).unwrap();
        assert!((r.makespan - 3.0).abs() < 1e-9);
    }

    #[test]
    fn run_uses_scheduler_plan() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 1.0);
        let _ = a;
        let g = b.finalize().unwrap();
        let r = run(&FairScheduler, &g, &Cluster::uniform(1)).unwrap();
        assert!((r.makespan - 1.0).abs() < 1e-9);
    }
}
