//! Tetris/Graphene-flavoured packing baseline (§2.1, related work).
//!
//! Network-aware DAG schedulers model bandwidth as one more divisible
//! resource and pack greedily; the usual tie-breaker is
//! "longest remaining work first" (Graphene's troublesome-task boost).
//! We model that as: priority = total downstream work, served by the
//! strict-priority fluid policy. Unlike the MXDAG scheduler there is no
//! Copath / slack reasoning and no pipelining.

use super::{Plan, Scheduler};
use crate::mxdag::MXDag;
use crate::sim::{Annotations, Cluster, Policy, QueueDiscipline};

/// The Tetris/Graphene-flavoured packing baseline scheduler.
#[derive(Debug, Clone, Copy, Default)]
pub struct PackingScheduler;

impl PackingScheduler {
    /// Total work (sum of sizes) on the heaviest downstream path of each
    /// task — the packing score.
    pub fn downstream_work(dag: &MXDag) -> Vec<f64> {
        let mut down = vec![0.0; dag.len()];
        for &u in dag.topo().iter().rev() {
            let best = dag
                .succs(u)
                .iter()
                .map(|&s| down[s])
                .fold(0.0, f64::max);
            down[u] = best + dag.task(u).size;
        }
        down
    }
}

impl Scheduler for PackingScheduler {
    fn name(&self) -> &'static str {
        "packing"
    }
    fn plan(&self, dag: &MXDag, _cluster: &Cluster) -> Plan {
        let down = Self::downstream_work(dag);
        // rank to integer priorities
        let mut order: Vec<usize> = (0..dag.len()).collect();
        order.sort_by(|&a, &b| down[a].partial_cmp(&down[b]).unwrap());
        let mut ann = Annotations::default();
        for (rank, &t) in order.iter().enumerate() {
            ann.priorities.insert(t, rank as i64);
        }
        Plan { ann, policy: Policy::priority() }
    }
    /// Static priorities (downstream-work rank) fixed at planning time.
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::PRIORITY]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::run;
    use crate::sim::Cluster;

    #[test]
    fn downstream_work_is_longest_path_weight() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 1.0);
        let f1 = b.flow("f1", 0, 1, 5.0);
        let f2 = b.flow("f2", 0, 2, 1.0);
        let c = b.compute("c", 1, 1.0);
        b.dep(a, f1).dep(a, f2).dep(f1, c).dep(f2, c);
        let g = b.finalize().unwrap();
        let down = PackingScheduler::downstream_work(&g);
        assert_eq!(down[a], 7.0); // a + f1 + c
        assert_eq!(down[f1], 6.0);
        assert_eq!(down[f2], 2.0);
    }

    #[test]
    fn heavy_branch_prioritized() {
        let mut b = MXDag::builder();
        let a = b.compute("a", 0, 0.0);
        let f1 = b.flow("f1", 0, 1, 2.0);
        let heavy = b.compute("heavy", 1, 10.0);
        let f2 = b.flow("f2", 0, 2, 2.0);
        let light = b.compute("light", 2, 1.0);
        b.dep(a, f1).dep(f1, heavy).dep(a, f2).dep(f2, light);
        let g = b.finalize().unwrap();
        let r = run(&PackingScheduler, &g, &Cluster::uniform(3)).unwrap();
        // f1 gets the uplink first: heavy starts at 2
        assert!((r.start_of(heavy) - 2.0).abs() < 1e-9);
        assert!((r.finish_of(light) - 5.0).abs() < 1e-9); // f2 2->4, light 4->5
    }
}
