//! The MXDAG co-scheduler — Principle 1 (§4.1).
//!
//! *"Prioritize the critical path over non-critical paths within any
//! Copath, without letting the non-critical paths have longer completion
//! time than the critical path."*
//!
//! Mechanism:
//! 1. CPM over the MXDAG ([`cpm_on`]: durations = `Size` divided by the
//!    task's per-path bottleneck rate under the cluster topology) gives
//!    slack per task; priority = criticality rank; NICs and cores serve
//!    strictly by priority (fair within a level).
//! 2. Pipelining is decided by *what-if search*: a pipelineable task is
//!    only pipelined if the simulated JCT shrinks (§4.1: "the pipelines
//!    will only be applied when they can shrink the overall execution
//!    time") — this is what rejects Fig. 3 case 3.

use super::{EvalContext, Plan, Scheduler};
use crate::mxdag::{cpm_with, Cpm, CpmCache, MXDag, TaskId, TaskKind};
use crate::sim::{Annotations, Cluster, Policy, QueueDiscipline, SimKind};
use crate::util::par::par_map_with;

/// The MXDAG co-scheduler (Principle 1).
#[derive(Debug, Clone)]
pub struct MxScheduler {
    /// Run the greedy pipeline what-if search (candidate tasks ordered by
    /// criticality; keep a pipeline only if JCT improves).
    pub pipeline_search: bool,
    /// Improvement threshold for keeping a pipeline decision.
    pub min_gain: f64,
    /// Budget for what-if evaluations (each costs one simulation); the
    /// most-critical moves are tried first, so a small budget keeps
    /// planning online-fast on large DAGs.
    pub max_moves: usize,
    /// Worker threads for the move-budget what-if evaluations. `1`
    /// (default) is the fully sequential greedy search; `> 1` scores
    /// candidate moves in parallel *rounds* of this size and accepts
    /// the best improving move of each round. Scores are exact
    /// simulations either way and the search is deterministic per
    /// thread count, but the greedy *trajectory* (which improving moves
    /// compose) legitimately depends on the round size — unlike
    /// [`crate::whatif::explore`], whose results are bit-identical
    /// across thread counts.
    pub threads: usize,
}

impl Default for MxScheduler {
    fn default() -> Self {
        MxScheduler { pipeline_search: true, min_gain: 1e-9, max_moves: 64, threads: 1 }
    }
}

/// The per-task durations [`cpm_on`] costs against `cluster`:
/// `size / solo-bottleneck-rate`, so a flow squeezed through an
/// oversubscribed aggregation link (or a degraded NIC/core) is costed
/// by its real per-path bandwidth, not the unit-NIC assumption. On a
/// uniform big-switch cluster every solo rate is 1 and this reduces
/// exactly to `Size(v)`. Dummies keep their (zero) size; a dead
/// resource falls back to the optimistic cost.
pub fn cpm_durations(dag: &MXDag, cluster: &Cluster) -> Vec<f64> {
    let caps = cluster.capacities();
    dag.tasks()
        .iter()
        .map(|t| {
            let kind = match t.kind {
                TaskKind::Compute { host } => SimKind::Compute { host },
                TaskKind::Flow { src, dst } => SimKind::Flow { src, dst },
                TaskKind::Start | TaskKind::End => return t.size,
            };
            let rate = cluster.solo_rate_with(&caps, &kind);
            if rate > 1e-12 {
                t.size / rate
            } else {
                t.size // dead resource: fall back to the optimistic cost
            }
        })
        .collect()
}

/// CPM over [`cpm_durations`] — the full-pass spelling, kept as the
/// bitwise oracle the incremental [`CpmCache`] patching is tested
/// against.
pub fn cpm_on(dag: &MXDag, cluster: &Cluster) -> Cpm {
    cpm_with(dag, &cpm_durations(dag, cluster))
}

/// Duration-domain pipeline unit of `t`: the first-chunk latency Eq. 2
/// charges, i.e. `Unit/Size` of the task's costed duration.
fn unit_dur(dag: &MXDag, dur0: &[f64], t: TaskId) -> f64 {
    let task = dag.task(t);
    if task.size > 0.0 {
        dur0[t] * (task.unit / task.size)
    } else {
        dur0[t]
    }
}

/// Eq. 2 ranking model for an accepted pipelined pair `u → v`: the
/// pair's combined contention-free length is
/// `max(d_u, d_v) + min(U_u, U_v)` (everything in duration domain), so
/// `v`'s effective ranked duration becomes that total minus `u`'s
/// unchanged `d_u`. This is the duration patch the move loop feeds
/// [`CpmCache::update`] so candidate ranking tracks the evolving plan —
/// a *ranking* heuristic only; move acceptance is always decided by the
/// simulation.
fn pipelined_pair_duration(dag: &MXDag, dur0: &[f64], u: TaskId, v: TaskId) -> f64 {
    let unit = unit_dur(dag, dur0, u).min(unit_dur(dag, dur0, v));
    (dur0[u].max(dur0[v]) + unit - dur0[u]).max(unit)
}

impl MxScheduler {
    pub fn without_pipelining() -> Self {
        MxScheduler { pipeline_search: false, ..Default::default() }
    }

    /// Default scheduler with `threads` what-if workers (see the
    /// `threads` field for the round semantics).
    pub fn with_threads(threads: usize) -> Self {
        MxScheduler { threads: threads.max(1), ..Default::default() }
    }

    /// The priority-only plan from an already-computed costed CPM pass
    /// (no pipeline search). `plan` computes that pass once and shares
    /// it with the move search.
    fn priority_plan(dag: &MXDag, c: &Cpm) -> Plan {
        let prios = c.priorities();
        let mut ann = Annotations::default();
        for t in dag.real_tasks() {
            ann.priorities.insert(t, prios[t]);
        }
        Plan { ann, policy: Policy::priority() }
    }

    /// Greedy pipeline what-if search on top of `plan`.
    ///
    /// Candidate moves are (a) adjacent pipelineable *pairs* u→v — a
    /// pipeline only overlaps anything when both producer and consumer
    /// chunk, so single toggles cannot discover the useful moves — and
    /// (b) single tasks (useful once a chain partner is already in).
    ///
    /// Each round the pending moves are *re-ranked* by min member slack
    /// under a [`CpmCache`] whose durations track the plan: an accepted
    /// pair patches the consumer's effective duration (Eq. 2, see
    /// [`pipelined_pair_duration`]) and the cache repairs the cone
    /// incrementally — the full `cpm_on` recompute this replaces is
    /// `O(V+E)` per accepted move. Scoring goes through the shared
    /// [`EvalContext`] (serial) or a batch of per-worker contexts
    /// (`threads > 1`), consuming one unit of `max_moves` budget per
    /// evaluation either way.
    fn search_pipelines(
        &self,
        dag: &MXDag,
        cluster: &Cluster,
        ctx: &mut EvalContext<'_>,
        dur0: Vec<f64>,
        c0: Cpm,
        mut plan: Plan,
    ) -> Plan {
        // `c0` is the pass `plan` already paid for over `dur0`; the
        // cache starts from it instead of re-running the full fold
        let mut cache = CpmCache::from_parts(dag, dur0.clone(), c0);
        let mut pending: Vec<Vec<TaskId>> = Vec::new();
        for u in dag.real_tasks() {
            if !dag.task(u).pipelineable() {
                continue;
            }
            for &v in dag.succs(u) {
                if !dag.task(v).kind.is_dummy() && dag.task(v).pipelineable() {
                    pending.push(vec![u, v]);
                }
            }
            pending.push(vec![u]);
        }

        let Ok(base) = ctx.evaluate(&plan) else {
            return plan;
        };
        let mut best_ms = base.makespan;
        let mut budget = self.max_moves;
        let threads = self.threads.max(1);
        // worker contexts are built once and stay warm across rounds —
        // every round reuses their cached expansions and engine scratch
        let mut worker_ctxs: Vec<EvalContext<'_>> = if threads > 1 {
            (0..threads).map(|_| EvalContext::new(dag, cluster)).collect()
        } else {
            Vec::new()
        };
        // the ranking only shifts when an accepted move patches the
        // cache, so sort lazily: retain/drain preserve relative order,
        // and a round with no accepted move reuses the standing order
        let mut ranking_stale = true;
        while budget > 0 {
            pending.retain(|m| !m.iter().all(|t| plan.ann.pipelined.contains(t)));
            if pending.is_empty() {
                break;
            }
            // most critical move first (min member slack) under the
            // *current* effective durations; the sort is stable, so
            // equally-critical moves keep generation order
            if ranking_stale {
                let slack = &cache.cpm().slack;
                let key = |m: &Vec<TaskId>| {
                    m.iter().map(|&t| slack[t]).fold(f64::INFINITY, f64::min)
                };
                pending.sort_by(|a, b| key(a).partial_cmp(&key(b)).unwrap());
                ranking_stale = false;
            }
            let round = threads.min(budget).min(pending.len());
            let moves: Vec<Vec<TaskId>> = pending.drain(..round).collect();
            budget -= round;
            let trials: Vec<Plan> = moves
                .iter()
                .map(|mv| {
                    let mut trial = plan.clone();
                    for &t in mv {
                        if !trial.ann.pipelined.contains(&t) {
                            trial.ann.pipelined.push(t);
                        }
                    }
                    trial
                })
                .collect();
            let scores: Vec<Option<f64>> = if threads > 1 && trials.len() > 1 {
                par_map_with(&trials, &mut worker_ctxs, |wctx, _, trial| {
                    wctx.evaluate(trial).ok().map(|r| r.makespan)
                })
            } else {
                trials
                    .iter()
                    .map(|trial| ctx.evaluate(trial).ok().map(|r| r.makespan))
                    .collect()
            };
            let mut winner: Option<usize> = None;
            for (i, s) in scores.iter().enumerate() {
                if let Some(ms) = *s {
                    let beats_round = match winner {
                        Some(w) => ms < scores[w].expect("winner has a score"),
                        None => true,
                    };
                    if ms < best_ms - self.min_gain && beats_round {
                        winner = Some(i);
                    }
                }
            }
            if let Some(i) = winner {
                best_ms = scores[i].expect("winner has a score");
                plan = trials[i].clone();
                if let [u, v] = moves[i][..] {
                    cache.update(dag, &[(v, pipelined_pair_duration(dag, &dur0, u, v))]);
                    ranking_stale = true;
                }
            }
        }
        plan
    }
}

impl Scheduler for MxScheduler {
    fn name(&self) -> &'static str {
        "mxdag"
    }

    fn plan(&self, dag: &MXDag, cluster: &Cluster) -> Plan {
        // Principle 1's guard ("without letting the non-critical paths
        // have longer completion time than the critical path") can be
        // violated by over-serialization on symmetric DAGs, where strict
        // priority idles downstream NICs. The co-scheduler has the global
        // view, so it checks its priority plan against plain fair sharing
        // and keeps the better one before searching pipelines. Every
        // evaluation in this method shares one context: the guard's two
        // plans share the unpipelined expansion, and the search reuses
        // the engine scratch throughout. The costed CPM pass is also
        // computed exactly once — the priority plan ranks by it and the
        // search's incremental cache starts from it.
        let mut ctx = EvalContext::new(dag, cluster);
        let dur0 = cpm_durations(dag, cluster);
        let c0 = cpm_with(dag, &dur0);
        let prio_plan = Self::priority_plan(dag, &c0);
        let fair_plan = Plan::fair();
        let plan = match (ctx.evaluate(&prio_plan), ctx.evaluate(&fair_plan)) {
            (Ok(p), Ok(f)) if f.makespan < p.makespan - self.min_gain => fair_plan,
            _ => prio_plan,
        };
        if self.pipeline_search {
            self.search_pipelines(dag, cluster, &mut ctx, dur0, c0, plan)
        } else {
            plan
        }
    }

    /// Reactive replanning after cluster churn (fabric degradation,
    /// stragglers, trunk failure): Eq. 2 ranking and the pipeline
    /// what-if search are pure functions of `(dag, cluster)` — the
    /// costed CPM pass re-runs [`cpm_durations`] against the *current*
    /// capacities and every what-if evaluation goes through a fresh
    /// [`EvalContext`] on the degraded cluster, so priorities that were
    /// correct under nominal NIC rates flip when an oversubscribed or
    /// degraded fabric link becomes the real bottleneck (see the
    /// `replan_reacts_to_degraded_fabric` test). The previous plan is
    /// not reused: stale pipelining decisions were accepted against
    /// simulations of a cluster that no longer exists.
    fn replan(&self, dag: &MXDag, cluster: &Cluster, _previous: &Plan) -> Plan {
        self.plan(dag, cluster)
    }

    /// Critical-path static priorities; may fall back to plain fair
    /// sharing when the what-if comparison favours it (see `plan`).
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::PRIORITY, QueueDiscipline::FAIR]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{evaluate, run, FairScheduler};
    use crate::sim::Cluster;

    /// Fig. 1: co-scheduling prioritises flow 1 over flow 3 so the
    /// downstream task starts at T2 < T1.
    fn fig1_dag() -> MXDag {
        let mut b = MXDag::builder();
        let a = b.compute("A", 0, 0.0);
        let f1 = b.flow("f1", 0, 1, 1.0);
        let bt = b.compute("B", 1, 1.0);
        let f2 = b.flow("f2", 1, 2, 1.0);
        let f3 = b.flow("f3", 0, 2, 1.0);
        let c = b.compute("C", 2, 1.0);
        b.chain(&[a, f1, bt, f2, c]);
        b.dep(a, f3).dep(f3, c);
        b.finalize().unwrap()
    }

    #[test]
    fn fig1_beats_fair() {
        let g = fig1_dag();
        let cluster = Cluster::uniform(3);
        let fair = run(&FairScheduler, &g, &cluster).unwrap();
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
        // fair: f1 & f3 share -> f1 at 2, B at 3, f2 at 4, C at 5 (T1)
        assert!((fair.makespan - 5.0).abs() < 1e-9, "fair {}", fair.makespan);
        // mx: f1 first (critical), f3 next; C starts at 3, ends 4 (T2)
        assert!((mx.makespan - 4.0).abs() < 1e-9, "mx {}", mx.makespan);
    }

    #[test]
    fn noncritical_not_overdelayed() {
        // Principle 1's guard: non-critical path must not become longer
        // than the critical path.
        let g = fig1_dag();
        let cluster = Cluster::uniform(3);
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
        let crit_finish = mx.finish_of(g.by_name("f2").unwrap());
        let noncrit_finish = mx.finish_of(g.by_name("f3").unwrap());
        assert!(noncrit_finish <= crit_finish + 1e-9);
    }

    #[test]
    fn pipeline_search_keeps_only_helpful() {
        // producer(4,u=1) -> flow(4,u=1): pipelining shrinks 8 -> 5.
        let mut b = MXDag::builder();
        let p = b.compute_full("p", 0, 4.0, 1.0);
        let f = b.flow_full("f", 0, 1, 4.0, 1.0);
        b.dep(p, f);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let s = MxScheduler::default();
        let plan = s.plan(&g, &cluster);
        assert!(!plan.ann.pipelined.is_empty(), "should adopt helpful pipeline");
        let r = evaluate(&g, &cluster, &plan).unwrap();
        assert!((r.makespan - 5.0).abs() < 1e-9, "got {}", r.makespan);
    }

    /// `threads > 1` scores whole rounds in parallel but must still
    /// find the same obviously-best move here and emit a plan the
    /// simulation accepts.
    #[test]
    fn parallel_move_rounds_find_helpful_pipeline() {
        let mut b = MXDag::builder();
        let p = b.compute_full("p", 0, 4.0, 1.0);
        let f = b.flow_full("f", 0, 1, 4.0, 1.0);
        b.dep(p, f);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let s = MxScheduler::with_threads(4);
        let plan = s.plan(&g, &cluster);
        assert!(!plan.ann.pipelined.is_empty(), "should adopt helpful pipeline");
        let r = evaluate(&g, &cluster, &plan).unwrap();
        assert!((r.makespan - 5.0).abs() < 1e-9, "got {}", r.makespan);
    }

    #[test]
    fn pipeline_search_rejects_harmful() {
        // Fig. 3 case 3 in miniature: pipelining f3 with A makes f3
        // contend with critical f1 on A's uplink.
        let mut b = MXDag::builder();
        let a = b.compute_full("A", 0, 2.0, 0.5);
        let f1 = b.flow("f1", 0, 1, 2.0);
        let bt = b.compute("B", 1, 2.0);
        let f3 = b.flow_full("f3", 0, 2, 2.0, 0.5);
        let c = b.compute("C", 2, 0.5);
        b.chain(&[a, f1, bt]);
        b.dep(a, f3).dep(f3, c);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(3);
        let s = MxScheduler::default();
        let plan = s.plan(&g, &cluster);
        let with_plan = evaluate(&g, &cluster, &plan).unwrap();
        // force-pipeline everything for comparison
        let mut forced = plan.clone();
        forced.ann.pipelined = vec![a, f1, bt, f3, c]
            .into_iter()
            .filter(|&t| g.task(t).pipelineable())
            .collect();
        let with_forced = evaluate(&g, &cluster, &forced).unwrap();
        assert!(with_plan.makespan <= with_forced.makespan + 1e-9);
    }

    /// Topology-aware CPM: a size-2 flow squeezed through a 0.5-capacity
    /// aggregation link really takes 4 — longer than the size-3
    /// intra-rack flow it contends with on the shared downlink — so the
    /// co-scheduler must prioritize it. Size-based CPM would pick the
    /// size-3 flow and serialize the wrong way (JCT 7 instead of 5).
    #[test]
    fn oversub_flips_critical_flow_priority() {
        let mut b = MXDag::builder();
        let fx = b.flow("fx", 2, 3, 3.0); // intra rack {2,3}
        let fy = b.flow("fy", 0, 3, 2.0); // cross-rack, same dst NIC
        let g = b.finalize().unwrap();
        let cluster = Cluster::oversubscribed(4, 2, 4.0); // agg cap 0.5

        let s = MxScheduler::without_pipelining();
        let plan = s.plan(&g, &cluster);
        if plan.policy == Policy::priority() {
            assert!(
                plan.ann.priorities[&fy] > plan.ann.priorities[&fx],
                "cross-rack flow must outrank the intra-rack one: {:?}",
                plan.ann.priorities
            );
        }
        let r = evaluate(&g, &cluster, &plan).unwrap();
        assert!(r.makespan <= 5.0 + 1e-9, "topology-aware plan: {}", r.makespan);
    }

    /// The replan hook reacting to fabric degradation: the plan drawn
    /// on the healthy uniform cluster ranks the size-3 intra-rack flow
    /// above the size-2 cross-rack one; after the aggregation layer
    /// degrades to 0.5 capacity the cross-rack flow really takes 4, and
    /// replanning on the degraded cluster must both flip that ordering
    /// and beat the stale plan's simulated JCT.
    #[test]
    fn replan_reacts_to_degraded_fabric() {
        let mut b = MXDag::builder();
        let fx = b.flow("fx", 2, 3, 3.0); // intra rack {2,3}
        let fy = b.flow("fy", 0, 3, 2.0); // cross-rack, same dst NIC
        let g = b.finalize().unwrap();

        let s = MxScheduler::without_pipelining();
        let healthy = Cluster::uniform(4);
        let stale = s.plan(&g, &healthy);
        if stale.policy == Policy::priority() {
            assert!(
                stale.ann.priorities[&fx] > stale.ann.priorities[&fy],
                "healthy cluster: bigger flow is the critical one: {:?}",
                stale.ann.priorities
            );
        }

        let degraded = Cluster::oversubscribed(4, 2, 4.0); // agg cap 0.5
        let fresh = s.replan(&g, &degraded, &stale);
        if fresh.policy == Policy::priority() {
            assert!(
                fresh.ann.priorities[&fy] > fresh.ann.priorities[&fx],
                "replan must flip to the fabric-squeezed flow: {:?}",
                fresh.ann.priorities
            );
        }
        let stale_ms = evaluate(&g, &degraded, &stale).unwrap().makespan;
        let fresh_ms = evaluate(&g, &degraded, &fresh).unwrap().makespan;
        assert!(
            fresh_ms + 1e-9 < stale_ms,
            "replanned {fresh_ms} must beat stale {stale_ms} on the degraded fabric"
        );
    }

    #[test]
    fn cpm_on_reduces_to_sizes_on_uniform_cluster() {
        let g = fig1_dag();
        let by_size = crate::mxdag::cpm(&g);
        let by_topo = cpm_on(&g, &Cluster::uniform(3));
        assert_eq!(by_size.makespan, by_topo.makespan);
        assert_eq!(by_size.priorities(), by_topo.priorities());
    }

    #[test]
    fn mx_never_worse_than_fair_on_chain() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        let f = b.flow("f", 0, 1, 2.0);
        let y = b.compute("y", 1, 3.0);
        b.chain(&[x, f, y]);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let fair = run(&FairScheduler, &g, &cluster).unwrap();
        let mx = run(&MxScheduler::default(), &g, &cluster).unwrap();
        assert!(mx.makespan <= fair.makespan + 1e-9);
    }
}
