//! The MXDAG co-scheduler — Principle 1 (§4.1).
//!
//! *"Prioritize the critical path over non-critical paths within any
//! Copath, without letting the non-critical paths have longer completion
//! time than the critical path."*
//!
//! Mechanism:
//! 1. CPM over the MXDAG ([`cpm_on`]: durations = `Size` divided by the
//!    task's per-path bottleneck rate under the cluster topology) gives
//!    slack per task; priority = criticality rank; NICs and cores serve
//!    strictly by priority (fair within a level).
//! 2. Pipelining is decided by *what-if search*: a pipelineable task is
//!    only pipelined if the simulated JCT shrinks (§4.1: "the pipelines
//!    will only be applied when they can shrink the overall execution
//!    time") — this is what rejects Fig. 3 case 3.

use super::{evaluate, Plan, Scheduler};
use crate::mxdag::{cpm_with, Cpm, MXDag, TaskId, TaskKind};
use crate::sim::{Annotations, Cluster, Policy, QueueDiscipline, SimKind};

/// The MXDAG co-scheduler (Principle 1).
#[derive(Debug, Clone)]
pub struct MxScheduler {
    /// Run the greedy pipeline what-if search (candidate tasks ordered by
    /// criticality; keep a pipeline only if JCT improves).
    pub pipeline_search: bool,
    /// Improvement threshold for keeping a pipeline decision.
    pub min_gain: f64,
    /// Budget for what-if evaluations (each costs one simulation); the
    /// most-critical moves are tried first, so a small budget keeps
    /// planning online-fast on large DAGs.
    pub max_moves: usize,
}

impl Default for MxScheduler {
    fn default() -> Self {
        MxScheduler { pipeline_search: true, min_gain: 1e-9, max_moves: 64 }
    }
}

/// CPM over durations costed against the cluster: a task's duration is
/// `size / solo-bottleneck-rate`, so a flow squeezed through an
/// oversubscribed aggregation link (or a degraded NIC/core) is costed by
/// its real per-path bandwidth, not the unit-NIC assumption. On a
/// uniform big-switch cluster every solo rate is 1 and this reduces
/// exactly to the size-based CPM.
pub fn cpm_on(dag: &MXDag, cluster: &Cluster) -> Cpm {
    let caps = cluster.capacities();
    let dur: Vec<f64> = dag
        .tasks()
        .iter()
        .map(|t| {
            let kind = match t.kind {
                TaskKind::Compute { host } => SimKind::Compute { host },
                TaskKind::Flow { src, dst } => SimKind::Flow { src, dst },
                TaskKind::Start | TaskKind::End => return t.size,
            };
            let rate = cluster.solo_rate_with(&caps, &kind);
            if rate > 1e-12 {
                t.size / rate
            } else {
                t.size // dead resource: fall back to the optimistic cost
            }
        })
        .collect();
    cpm_with(dag, &dur)
}

impl MxScheduler {
    pub fn without_pipelining() -> Self {
        MxScheduler { pipeline_search: false, ..Default::default() }
    }

    /// The priority-only plan (no pipeline search).
    fn base_plan(&self, dag: &MXDag, cluster: &Cluster) -> Plan {
        let c = cpm_on(dag, cluster);
        let prios = c.priorities();
        let mut ann = Annotations::default();
        for t in dag.real_tasks() {
            ann.priorities.insert(t, prios[t]);
        }
        Plan { ann, policy: Policy::priority() }
    }

    /// Greedy pipeline what-if search on top of `plan`.
    ///
    /// Candidate moves are (a) adjacent pipelineable *pairs* u→v — a
    /// pipeline only overlaps anything when both producer and consumer
    /// chunk, so single toggles cannot discover the useful moves — and
    /// (b) single tasks (useful once a chain partner is already in).
    fn search_pipelines(&self, dag: &MXDag, cluster: &Cluster, mut plan: Plan) -> Plan {
        let c = cpm_on(dag, cluster);
        let mut moves: Vec<Vec<TaskId>> = Vec::new();
        for u in dag.real_tasks() {
            if !dag.task(u).pipelineable() {
                continue;
            }
            for &v in dag.succs(u) {
                if !dag.task(v).kind.is_dummy() && dag.task(v).pipelineable() {
                    moves.push(vec![u, v]);
                }
            }
            moves.push(vec![u]);
        }
        // most critical move first (by min slack of its members)
        let key = |m: &Vec<TaskId>| {
            m.iter()
                .map(|&t| c.slack[t])
                .fold(f64::INFINITY, f64::min)
        };
        moves.sort_by(|a, b| key(a).partial_cmp(&key(b)).unwrap());
        moves.truncate(self.max_moves);

        let Ok(mut best) = evaluate(dag, cluster, &plan) else {
            return plan;
        };
        for mv in moves {
            if mv.iter().all(|t| plan.ann.pipelined.contains(t)) {
                continue;
            }
            let mut trial = plan.clone();
            for &t in &mv {
                if !trial.ann.pipelined.contains(&t) {
                    trial.ann.pipelined.push(t);
                }
            }
            if let Ok(r) = evaluate(dag, cluster, &trial) {
                if r.makespan < best.makespan - self.min_gain {
                    best = r;
                    plan = trial;
                }
            }
        }
        plan
    }
}

impl Scheduler for MxScheduler {
    fn name(&self) -> &'static str {
        "mxdag"
    }

    fn plan(&self, dag: &MXDag, cluster: &Cluster) -> Plan {
        // Principle 1's guard ("without letting the non-critical paths
        // have longer completion time than the critical path") can be
        // violated by over-serialization on symmetric DAGs, where strict
        // priority idles downstream NICs. The co-scheduler has the global
        // view, so it checks its priority plan against plain fair sharing
        // and keeps the better one before searching pipelines.
        let prio_plan = self.base_plan(dag, cluster);
        let fair_plan = Plan::fair();
        let plan = match (
            evaluate(dag, cluster, &prio_plan),
            evaluate(dag, cluster, &fair_plan),
        ) {
            (Ok(p), Ok(f)) if f.makespan < p.makespan - self.min_gain => fair_plan,
            _ => prio_plan,
        };
        if self.pipeline_search {
            self.search_pipelines(dag, cluster, plan)
        } else {
            plan
        }
    }

    /// Critical-path static priorities; may fall back to plain fair
    /// sharing when the what-if comparison favours it (see `plan`).
    fn disciplines(&self) -> &'static [QueueDiscipline] {
        &[QueueDiscipline::PRIORITY, QueueDiscipline::FAIR]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{run, FairScheduler};
    use crate::sim::Cluster;

    /// Fig. 1: co-scheduling prioritises flow 1 over flow 3 so the
    /// downstream task starts at T2 < T1.
    fn fig1_dag() -> MXDag {
        let mut b = MXDag::builder();
        let a = b.compute("A", 0, 0.0);
        let f1 = b.flow("f1", 0, 1, 1.0);
        let bt = b.compute("B", 1, 1.0);
        let f2 = b.flow("f2", 1, 2, 1.0);
        let f3 = b.flow("f3", 0, 2, 1.0);
        let c = b.compute("C", 2, 1.0);
        b.chain(&[a, f1, bt, f2, c]);
        b.dep(a, f3).dep(f3, c);
        b.finalize().unwrap()
    }

    #[test]
    fn fig1_beats_fair() {
        let g = fig1_dag();
        let cluster = Cluster::uniform(3);
        let fair = run(&FairScheduler, &g, &cluster).unwrap();
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
        // fair: f1 & f3 share -> f1 at 2, B at 3, f2 at 4, C at 5 (T1)
        assert!((fair.makespan - 5.0).abs() < 1e-9, "fair {}", fair.makespan);
        // mx: f1 first (critical), f3 next; C starts at 3, ends 4 (T2)
        assert!((mx.makespan - 4.0).abs() < 1e-9, "mx {}", mx.makespan);
    }

    #[test]
    fn noncritical_not_overdelayed() {
        // Principle 1's guard: non-critical path must not become longer
        // than the critical path.
        let g = fig1_dag();
        let cluster = Cluster::uniform(3);
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
        let crit_finish = mx.finish_of(g.by_name("f2").unwrap());
        let noncrit_finish = mx.finish_of(g.by_name("f3").unwrap());
        assert!(noncrit_finish <= crit_finish + 1e-9);
    }

    #[test]
    fn pipeline_search_keeps_only_helpful() {
        // producer(4,u=1) -> flow(4,u=1): pipelining shrinks 8 -> 5.
        let mut b = MXDag::builder();
        let p = b.compute_full("p", 0, 4.0, 1.0);
        let f = b.flow_full("f", 0, 1, 4.0, 1.0);
        b.dep(p, f);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let s = MxScheduler::default();
        let plan = s.plan(&g, &cluster);
        assert!(!plan.ann.pipelined.is_empty(), "should adopt helpful pipeline");
        let r = evaluate(&g, &cluster, &plan).unwrap();
        assert!((r.makespan - 5.0).abs() < 1e-9, "got {}", r.makespan);
    }

    #[test]
    fn pipeline_search_rejects_harmful() {
        // Fig. 3 case 3 in miniature: pipelining f3 with A makes f3
        // contend with critical f1 on A's uplink.
        let mut b = MXDag::builder();
        let a = b.compute_full("A", 0, 2.0, 0.5);
        let f1 = b.flow("f1", 0, 1, 2.0);
        let bt = b.compute("B", 1, 2.0);
        let f3 = b.flow_full("f3", 0, 2, 2.0, 0.5);
        let c = b.compute("C", 2, 0.5);
        b.chain(&[a, f1, bt]);
        b.dep(a, f3).dep(f3, c);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(3);
        let s = MxScheduler::default();
        let plan = s.plan(&g, &cluster);
        let with_plan = evaluate(&g, &cluster, &plan).unwrap();
        // force-pipeline everything for comparison
        let mut forced = plan.clone();
        forced.ann.pipelined = vec![a, f1, bt, f3, c]
            .into_iter()
            .filter(|&t| g.task(t).pipelineable())
            .collect();
        let with_forced = evaluate(&g, &cluster, &forced).unwrap();
        assert!(with_plan.makespan <= with_forced.makespan + 1e-9);
    }

    /// Topology-aware CPM: a size-2 flow squeezed through a 0.5-capacity
    /// aggregation link really takes 4 — longer than the size-3
    /// intra-rack flow it contends with on the shared downlink — so the
    /// co-scheduler must prioritize it. Size-based CPM would pick the
    /// size-3 flow and serialize the wrong way (JCT 7 instead of 5).
    #[test]
    fn oversub_flips_critical_flow_priority() {
        let mut b = MXDag::builder();
        let fx = b.flow("fx", 2, 3, 3.0); // intra rack {2,3}
        let fy = b.flow("fy", 0, 3, 2.0); // cross-rack, same dst NIC
        let g = b.finalize().unwrap();
        let cluster = Cluster::oversubscribed(4, 2, 4.0); // agg cap 0.5

        let s = MxScheduler::without_pipelining();
        let plan = s.plan(&g, &cluster);
        if plan.policy == Policy::priority() {
            assert!(
                plan.ann.priorities[&fy] > plan.ann.priorities[&fx],
                "cross-rack flow must outrank the intra-rack one: {:?}",
                plan.ann.priorities
            );
        }
        let r = evaluate(&g, &cluster, &plan).unwrap();
        assert!(r.makespan <= 5.0 + 1e-9, "topology-aware plan: {}", r.makespan);
    }

    #[test]
    fn cpm_on_reduces_to_sizes_on_uniform_cluster() {
        let g = fig1_dag();
        let by_size = crate::mxdag::cpm(&g);
        let by_topo = cpm_on(&g, &Cluster::uniform(3));
        assert_eq!(by_size.makespan, by_topo.makespan);
        assert_eq!(by_size.priorities(), by_topo.priorities());
    }

    #[test]
    fn mx_never_worse_than_fair_on_chain() {
        let mut b = MXDag::builder();
        let x = b.compute("x", 0, 1.0);
        let f = b.flow("f", 0, 1, 2.0);
        let y = b.compute("y", 1, 3.0);
        b.chain(&[x, f, y]);
        let g = b.finalize().unwrap();
        let cluster = Cluster::uniform(2);
        let fair = run(&FairScheduler, &g, &cluster).unwrap();
        let mx = run(&MxScheduler::default(), &g, &cluster).unwrap();
        assert!(mx.makespan <= fair.makespan + 1e-9);
    }
}
