//! Plan-space evaluation throughput — the outer loop the batched
//! plan-space engine accelerates. Two stories:
//!
//! 1. *Plans/s* for a what-if pipeline sweep under three regimes:
//!    **cold** (one fresh `sched::evaluate` per hypothetical — the
//!    pre-refactor cost profile), **context** (`whatif::explore` at one
//!    thread: cached expansions + cluster footprints + reusable engine
//!    scratch), and **context + parallel** (`explore` at 2 and 4
//!    workers, each with its own context).
//! 2. *CPM repair rate*: full `cpm_with` passes/s vs incremental
//!    `CpmCache::update` patches/s over the same random duration-toggle
//!    stream (the move-loop re-ranking cost).
//!
//! Oracles run on every invocation, before timing: the parallel sweeps
//! at threads ∈ {1, 4} (plus 2) must be bit-identical — baseline,
//! labels, JCT/delta bits, captured errors, order — and every cold JCT
//! must equal its context-reuse twin bitwise; the CPM cache must match
//! the full pass bitwise after every patch. `BENCH_SMOKE=1` (the CI
//! bench-smoke job) shrinks sizes and still runs every oracle.
//!
//! Results are printed as tables (README §Performance) and persisted to
//! `BENCH_sim.json` (section `whatif_scaling`) for cross-PR tracking.

use std::time::Instant;

use mxdag::mxdag::CpmCache;
use mxdag::sched::mxsched::cpm_durations;
use mxdag::sched::{evaluate, Plan};
use mxdag::sim::{Cluster, Policy};
use mxdag::util::bench::{write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::util::rng::Rng;
use mxdag::whatif::{explore, single_pipeline_toggles, Exploration, Hypothetical};
use mxdag::workloads::{random_dag, RandomParams};
use mxdag::mxdag::cpm_with;

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn shapes() -> Vec<(usize, usize)> {
    if smoke() {
        vec![(4, 4)]
    } else {
        vec![(8, 8), (14, 14), (20, 20)]
    }
}

fn assert_explorations_identical(tag: &str, a: &Exploration, b: &Exploration) {
    assert_eq!(a.baseline.to_bits(), b.baseline.to_bits(), "{tag}: baseline");
    assert_eq!(a.results.len(), b.results.len(), "{tag}: result count");
    for (x, y) in a.results.iter().zip(b.results.iter()) {
        assert_eq!(x.label, y.label, "{tag}");
        match (&x.outcome, &y.outcome) {
            (Ok((ja, da)), Ok((jb, db))) => {
                assert_eq!(ja.to_bits(), jb.to_bits(), "{tag}: {} jct", x.label);
                assert_eq!(da.to_bits(), db.to_bits(), "{tag}: {} delta", x.label);
            }
            (Err(ea), Err(eb)) => assert_eq!(ea, eb, "{tag}: {}", x.label),
            (p, q) => panic!("{tag}: {} outcome kind diverged: {p:?} vs {q:?}", x.label),
        }
    }
}

/// Best-of-`reps` wall time for `f` (which must be pure).
fn timed<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn plans_per_sec() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "what-if sweep plans/s (cold evaluate vs reusable context vs parallel explore)",
        &["hypos", "cold", "context", "par x2", "par x4", "ctx/cold", "x4/ctx"],
    );
    let mut rows = Vec::new();
    for (layers, width) in shapes() {
        let p = RandomParams {
            layers,
            width,
            hosts,
            seed: 23,
            pipe_frac: 0.5,
            ..Default::default()
        };
        let g = random_dag(&p);
        let base = Plan { ann: Default::default(), policy: Policy::fifo() };
        let mut hypos = single_pipeline_toggles(&g, &base);
        // pair toggles widen the sweep beyond the single-toggle set
        let piped: Vec<_> = g.real_tasks().filter(|&t| g.task(t).pipelineable()).collect();
        for w in piped.windows(2) {
            hypos.push(Hypothetical::Pipeline(vec![w[0], w[1]]));
        }
        // bound the sweep so full-size runs stay in seconds; announce
        // the cut rather than silently truncating coverage
        let total = hypos.len();
        hypos.truncate(256);
        if hypos.len() < total {
            println!("(sweep capped at {} of {total} hypotheticals)", hypos.len());
        }
        let n_hypos = hypos.len();
        assert!(n_hypos >= 2, "generator must yield pipelineable tasks");

        // -- oracles first (untimed): threads {1, 2, 4} bit-identical,
        //    cold JCTs == context JCTs bitwise
        let serial = explore(&g, &cluster, &base, &hypos, 1).unwrap();
        for threads in [2usize, 4] {
            let par = explore(&g, &cluster, &base, &hypos, threads).unwrap();
            assert_explorations_identical(&format!("threads {threads}"), &serial, &par);
        }
        for (h, w) in hypos.iter().zip(serial.results.iter()) {
            let Hypothetical::Pipeline(ts) = h else { unreachable!() };
            let mut trial = base.clone();
            for &t in ts {
                if !trial.ann.pipelined.contains(&t) {
                    trial.ann.pipelined.push(t);
                }
            }
            let cold = evaluate(&g, &cluster, &trial).unwrap();
            assert_eq!(
                cold.makespan.to_bits(),
                w.jct().unwrap().to_bits(),
                "context reuse must be bit-identical to cold evaluation"
            );
        }

        // -- timings (the +1 counts the baseline evaluation each
        //    explore pays; the cold loop pays it too)
        let reps = if smoke() { 1 } else { 3 };
        let t_cold = timed(reps, || {
            let _ = evaluate(&g, &cluster, &base).unwrap();
            for h in &hypos {
                let Hypothetical::Pipeline(ts) = h else { unreachable!() };
                let mut trial = base.clone();
                for &t in ts {
                    if !trial.ann.pipelined.contains(&t) {
                        trial.ann.pipelined.push(t);
                    }
                }
                let _ = evaluate(&g, &cluster, &trial).unwrap();
            }
        });
        let t_ctx = timed(reps, || {
            let _ = explore(&g, &cluster, &base, &hypos, 1).unwrap();
        });
        let t_par2 = timed(reps, || {
            let _ = explore(&g, &cluster, &base, &hypos, 2).unwrap();
        });
        let t_par4 = timed(reps, || {
            let _ = explore(&g, &cluster, &base, &hypos, 4).unwrap();
        });
        let pps = |t: f64| (n_hypos + 1) as f64 / t;
        let tasks = g.real_tasks().count();
        table.row(
            &format!("{tasks} tasks"),
            &[
                format!("{n_hypos}"),
                format!("{:.1}", pps(t_cold)),
                format!("{:.1}", pps(t_ctx)),
                format!("{:.1}", pps(t_par2)),
                format!("{:.1}", pps(t_par4)),
                format!("{:.2}x", t_cold / t_ctx),
                format!("{:.2}x", t_ctx / t_par4),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(tasks as f64)),
            ("hypos", Json::Num(n_hypos as f64)),
            ("plans_per_sec_cold", Json::Num(pps(t_cold))),
            ("plans_per_sec_context", Json::Num(pps(t_ctx))),
            ("plans_per_sec_par2", Json::Num(pps(t_par2))),
            ("plans_per_sec_par4", Json::Num(pps(t_par4))),
            ("speedup_context_vs_cold", Json::Num(t_cold / t_ctx)),
            ("speedup_par4_vs_context", Json::Num(t_ctx / t_par4)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn cpm_repair_rate() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "CPM repair rate (full cpm_with passes/s vs CpmCache incremental patches/s)",
        &["tasks", "full/s", "incremental/s", "speedup"],
    );
    let mut rows = Vec::new();
    let shapes = if smoke() { vec![(6, 6)] } else { vec![(12, 12), (20, 20), (30, 30)] };
    for (layers, width) in shapes {
        let p = RandomParams { layers, width, hosts, seed: 31, ..Default::default() };
        let g = random_dag(&p);
        let n = g.len();
        let dur0 = cpm_durations(&g, &cluster);

        // the shared toggle stream (deterministic)
        let rounds = if smoke() { 20 } else { 200 };
        let mut rng = Rng::new(0xBEEF ^ n as u64);
        let stream: Vec<Vec<(usize, f64)>> = (0..rounds)
            .map(|_| {
                (0..2)
                    .map(|_| (rng.below(n), rng.range_f64(0.0, 3.0)))
                    .collect()
            })
            .collect();

        // oracle first: the cache matches the full pass after every patch
        let mut cache = CpmCache::new(&g, dur0.clone());
        for changes in &stream {
            cache.update(&g, changes);
            let full = cpm_with(&g, cache.durations());
            assert_eq!(full.makespan.to_bits(), cache.cpm().makespan.to_bits());
            for i in 0..n {
                assert_eq!(full.slack[i].to_bits(), cache.cpm().slack[i].to_bits());
            }
            assert_eq!(full.critical, cache.cpm().critical);
        }

        let reps = if smoke() { 1 } else { 3 };
        let t_full = timed(reps, || {
            let mut dur = dur0.clone();
            for changes in &stream {
                for &(t, d) in changes {
                    dur[t] = d;
                }
                let _ = std::hint::black_box(cpm_with(&g, &dur));
            }
        });
        let t_inc = timed(reps, || {
            let mut cache = CpmCache::new(&g, dur0.clone());
            for changes in &stream {
                cache.update(&g, changes);
                std::hint::black_box(cache.cpm().makespan);
            }
        });
        let per_sec = |t: f64| rounds as f64 / t;
        table.row(
            &format!("{n}"),
            &[
                format!("{n}"),
                format!("{:.0}", per_sec(t_full)),
                format!("{:.0}", per_sec(t_inc)),
                format!("{:.2}x", t_full / t_inc),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(n as f64)),
            ("rounds", Json::Num(rounds as f64)),
            ("full_passes_per_sec", Json::Num(per_sec(t_full))),
            ("incremental_patches_per_sec", Json::Num(per_sec(t_inc))),
            ("speedup_incremental_vs_full", Json::Num(t_full / t_inc)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn main() {
    println!("== what-if parallel + CPM-cache oracles run before every timing ==");
    let plans = plans_per_sec();
    let cpm = cpm_repair_rate();
    write_bench_json(
        "whatif_scaling",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke())),
            ("plans", plans),
            ("cpm", cpm),
        ]),
    );
    println!("\nwrote BENCH_sim.json (section `whatif_scaling`)");
}
