//! Oversubscription sweep: how the MXDAG co-scheduler's advantage over
//! the fair-share and coflow baselines moves as the leaf/spine fabric
//! gets more oversubscribed (ratio 1:1 → 16:1).
//!
//! Scenario: `workloads::oversub::incast_with_chain` — a critical
//! compute→flow→compute chain whose flow crosses racks, plus background
//! incast flows sharing only the aggregation links. The reported metric
//! is the chain's JCT (finish of `C`); the background flows are load,
//! not deliverable.

use mxdag::sched::{run, CoflowScheduler, FairScheduler, Grouping, MxScheduler};
use mxdag::util::bench::Table;
use mxdag::workloads::oversub::{incast_with_chain, two_rack_cluster};

fn main() {
    let (g, c, sides) = incast_with_chain(6);
    let fc = g.by_name("fc").unwrap();
    let stage: Vec<usize> = std::iter::once(fc).chain(sides.iter().copied()).collect();
    let mut t = Table::new(
        "oversubscription sweep — chain JCT (4 hosts, 2 racks, 6-flow incast)",
        &["mxdag", "fair", "coflow(stage)", "fair/mx", "co/mx"],
    );
    let mut prev_gap = f64::NEG_INFINITY;
    for ratio in [1.0, 2.0, 4.0, 8.0, 16.0] {
        let cluster = two_rack_cluster(2, ratio);
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .finish_of(c);
        let fair = run(&FairScheduler, &g, &cluster).unwrap().finish_of(c);
        // the "one transfer stage" coflow view lumps the critical flow
        // with the incast — the Fig. 2 grouping ambiguity on a fabric
        let co = run(
            &CoflowScheduler::new(Grouping::Explicit(vec![stage.clone()])),
            &g,
            &cluster,
        )
        .unwrap()
        .finish_of(c);
        assert!(mx <= fair + 1e-9, "mx must not lose to fair at {ratio}");
        let gap = fair - mx;
        assert!(
            gap >= prev_gap - 1e-6,
            "co-scheduling advantage must widen with the ratio: \
             {prev_gap:.3} -> {gap:.3} at {ratio}"
        );
        prev_gap = gap;
        t.row_f64(&format!("ratio {ratio}:1"), &[mx, fair, co, fair / mx, co / mx]);
    }
    t.print();
    println!(
        "\nfair-share penalty on the critical chain grows to +{prev_gap:.1} time units at 16:1"
    );
}
