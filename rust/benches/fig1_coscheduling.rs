//! Fig. 1 — network-aware fair share vs compute/network co-scheduling.
//! Regenerates the T1/T2 comparison and sweeps the flow-size ratio to
//! show where co-scheduling's win grows.

use mxdag::sched::{run, FairScheduler, MxScheduler};
use mxdag::sim::Cluster;
use mxdag::util::bench::{bench, bench_header, Table};
use mxdag::mxdag::MXDag;
use mxdag::workloads::fig1_dag;

fn fig1_sized(flow: f64) -> MXDag {
    let mut b = MXDag::builder();
    let a = b.compute("A", 0, 0.0);
    let f1 = b.flow("f1", 0, 1, flow);
    let bt = b.compute("B", 1, 1.0);
    let f2 = b.flow("f2", 1, 2, flow);
    let f3 = b.flow("f3", 0, 2, flow);
    let c = b.compute("C", 2, 1.0);
    b.chain(&[a, f1, bt, f2, c]);
    b.dep(a, f3).dep(f3, c);
    b.finalize().unwrap()
}

fn main() {
    let cluster = Cluster::uniform(3);

    let g = fig1_dag();
    let fair = run(&FairScheduler, &g, &cluster).unwrap();
    let mx = run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
    let mut t = Table::new("Fig 1 — fair share (T1) vs co-scheduling (T2)", &["JCT", "C starts"]);
    let c = g.by_name("C").unwrap();
    t.row_f64("network-aware fair", &[fair.makespan, fair.start_of(c)]);
    t.row_f64("mxdag co-schedule", &[mx.makespan, mx.start_of(c)]);
    t.print();
    assert!(mx.makespan < fair.makespan, "paper's direction must hold");

    let mut t = Table::new("flow-size sweep (JCT)", &["fair", "mxdag", "speedup"]);
    for flow in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let g = fig1_sized(flow);
        let f = run(&FairScheduler, &g, &cluster).unwrap().makespan;
        let m = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        t.row_f64(&format!("flow={flow}"), &[f, m, f / m]);
    }
    t.print();

    bench_header("scheduling + simulation cost");
    bench("fair: plan+simulate fig1", || {
        run(&FairScheduler, &g, &cluster).unwrap();
    });
    bench("mxdag: plan+simulate fig1", || {
        run(&MxScheduler::without_pipelining(), &g, &cluster).unwrap();
    });
}
