//! Fig. 3 — pipelineability choices: off-critical pipelining is neutral
//! (case 1), critical-path pipelining helps (case 2), contending
//! pipelining hurts (case 3). Plus the MXDAG scheduler's automatic
//! what-if search, which adopts case-2 and refuses case-3.

use mxdag::sched::{evaluate, run, MxScheduler, Plan};
use mxdag::sim::{Annotations, Policy};
use mxdag::util::bench::{bench, bench_header, Table};
use mxdag::workloads::{fig3_dag, fig3_pipeline_sets, figs::fig3_cluster};

fn main() {
    let (g, _) = fig3_dag();
    let cluster = fig3_cluster();

    let mut results = Vec::new();
    let mut t = Table::new("Fig 3 — pipeline choices under the FIFO runtime", &["JCT"]);
    for (name, pipes) in fig3_pipeline_sets() {
        let pipelined = pipes.iter().map(|n| g.by_name(n).unwrap()).collect();
        let plan = Plan {
            ann: Annotations { pipelined, ..Default::default() },
            policy: Policy::fifo(),
        };
        let jct = evaluate(&g, &cluster, &plan).unwrap().makespan;
        t.row_f64(name, &[jct]);
        results.push(jct);
    }
    let mx = run(&MxScheduler::default(), &g, &cluster).unwrap().makespan;
    t.row_f64("mxdag auto (priority + search)", &[mx]);
    t.print();

    let (base, case1, case2, case3) = (results[0], results[1], results[2], results[3]);
    assert!((case1 - base).abs() < 1e-9, "case 1: no impact");
    assert!(case2 < base, "case 2: improves");
    assert!(case3 > base, "case 3: degrades");
    assert!(mx <= case2 + 1e-9, "auto search must find the best choice");
    println!("\ncase ordering holds: case2 {case2} < base {base} = case1 < case3 {case3}; auto {mx}");

    bench_header("pipeline search cost");
    bench("mxdag plan with what-if search", || {
        MxScheduler::default();
        let s = MxScheduler::default();
        let _ = mxdag::sched::Scheduler::plan(&s, &g, &cluster);
    });
}
