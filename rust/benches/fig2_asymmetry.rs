//! Fig. 2 — coflow's two failure modes: (c) asymmetric compute times on
//! a symmetric topology; (d) the Wukong asymmetric topology under all
//! three candidate coflow groupings (b1/b2/b3); (e) the same asymmetric
//! scenario re-run on a two-rack fabric at oversubscription ratios
//! 1:1 / 4:1 / 8:1.

use mxdag::sched::{run, CoflowScheduler, FairScheduler, Grouping, MxScheduler};
use mxdag::sim::{Cluster, Topology};
use mxdag::util::bench::Table;
use mxdag::workloads::{fig2a_dag, wukong_dag, WukongCoflows};

fn main() {
    // (c): sweep compute asymmetry t1/t2
    let cluster = Cluster::uniform(4);
    let mut t = Table::new(
        "Fig 2(c) — symmetric topology, asymmetric compute (t2=1)",
        &["mxdag", "coflow", "coflow/mxdag"],
    );
    for t1 in [1.0, 2.0, 3.0, 5.0] {
        let (g, flows) = fig2a_dag(t1, 1.0);
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        let co = run(
            &CoflowScheduler::new(Grouping::Explicit(vec![
                vec![flows[0], flows[1]],
                vec![flows[2], flows[3]],
            ])),
            &g,
            &cluster,
        )
        .unwrap()
        .makespan;
        t.row_f64(&format!("t1={t1}"), &[mx, co, co / mx]);
        assert!(mx <= co + 1e-9);
    }
    t.print();

    // (d): Wukong DAG under the three groupings
    let (g, flows) = wukong_dag();
    let cluster = Cluster::uniform(6);
    let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
        .unwrap()
        .makespan;
    let mut t = Table::new("Fig 2(d) — Wukong DAG", &["JCT", "vs mxdag"]);
    t.row_f64("mxdag per-flow", &[mx, 1.0]);
    for v in WukongCoflows::all() {
        let co = run(
            &CoflowScheduler::new(Grouping::Explicit(v.groups(&flows))),
            &g,
            &cluster,
        )
        .unwrap()
        .makespan;
        t.row_f64(v.label(), &[co, co / mx]);
        assert!(mx < co, "every coflow grouping must lose (paper Fig 2d)");
    }
    // auto groupings for reference
    for (label, grouping) in [
        ("coflow-auto-bydst", Grouping::ByDst),
        ("coflow-auto-bysrc", Grouping::BySrc),
        ("coflow-auto-bylevel", Grouping::ByLevel),
    ] {
        let co = run(&CoflowScheduler::new(grouping), &g, &cluster)
            .unwrap()
            .makespan;
        t.row_f64(label, &[co, co / mx]);
    }
    t.print();

    // (e): fig 2(c) scenario on a two-tier fabric, racks {A,B} / {C,D}.
    // Flows f2 (A→C) and f3 (B→D) cross racks and now share the
    // aggregation links; the sweep shows every scheduler's JCT degrading
    // with the ratio and mxdag staying ahead of plain fair sharing.
    let mut t = Table::new(
        "Fig 2(e) — asymmetric compute on an oversubscribed fabric (t1=3, t2=1)",
        &["mxdag", "fair", "coflow", "co/mx"],
    );
    for ratio in [1.0, 4.0, 8.0] {
        let (g, flows) = fig2a_dag(3.0, 1.0);
        let cluster = Cluster::uniform(4)
            .with_topology(Topology::Oversubscribed { racks: 2, ratio });
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        let fair = run(&FairScheduler, &g, &cluster).unwrap().makespan;
        let co = run(
            &CoflowScheduler::new(Grouping::Explicit(vec![
                vec![flows[0], flows[1]],
                vec![flows[2], flows[3]],
            ])),
            &g,
            &cluster,
        )
        .unwrap()
        .makespan;
        assert!(mx <= fair + 1e-9, "ratio {ratio}: mx {mx} vs fair {fair}");
        t.row_f64(&format!("ratio {ratio}:1"), &[mx, fair, co, co / mx]);
    }
    t.print();
}
