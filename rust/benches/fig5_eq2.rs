//! Fig. 5 / Eq. (2) — the pipelined-path closed form vs the chunk-level
//! simulation: Len = Σ Unit_i + max Size_i − max Unit_i (full resources).
//! The sim must match the analytic value exactly on unit-divisible sizes.

use mxdag::mxdag::{path, MXDag};
use mxdag::sim::{expand, simulate, Annotations, Cluster, SimConfig};
use mxdag::util::bench::{bench, bench_header, Table};

fn two_stage(s1: f64, u1: f64, s2: f64, u2: f64) -> (MXDag, usize, usize) {
    let mut b = MXDag::builder();
    let a = b.compute_full("producer", 0, s1, u1);
    let f = b.flow_full("stream", 0, 1, s2, u2);
    b.dep(a, f);
    (b.finalize().unwrap(), a, f)
}

fn main() {
    let cluster = Cluster::uniform(2);
    let mut t = Table::new(
        "Fig 5 / Eq 2 — analytic vs simulated pipelined pair",
        &["Eq.(2)", "simulated", "sequential"],
    );
    // aligned chunk counts: Eq.(2) is exact (see integration_sim for the
    // ±one-unit quantization bound on mismatched counts)
    let cases = [
        (4.0, 1.0, 4.0, 1.0),
        (8.0, 2.0, 4.0, 1.0),
        (6.0, 2.0, 9.0, 3.0),
        (10.0, 2.5, 2.0, 0.5),
        (5.0, 5.0, 5.0, 1.0), // producer not pipelineable
    ];
    for (s1, u1, s2, u2) in cases {
        let (g, a, f) = two_stage(s1, u1, s2, u2);
        let eq2 = if g.task(a).pipelineable() && g.task(f).pipelineable() {
            path::len_pipe(&g, &[a, f], &path::full_rsrc)
        } else {
            // one-sided: no overlap possible
            path::len_seq(&g, &[a, f], &path::full_rsrc)
        };
        let ann = Annotations { pipelined: vec![a, f], ..Default::default() };
        let sim = simulate(&expand(&g, &ann), &cluster, &SimConfig::default())
            .unwrap()
            .makespan;
        let seq = path::len_seq(&g, &[a, f], &path::full_rsrc);
        t.row_f64(&format!("S=({s1},{s2}) U=({u1},{u2})"), &[eq2, sim, seq]);
        assert!(
            (eq2 - sim).abs() < 1e-9,
            "Eq.(2) {eq2} must equal simulation {sim}"
        );
    }
    t.print();
    println!("\nEq.(2) == chunk-level simulation on all cases");

    bench_header("chunk-expansion + simulation cost");
    let (g, a, f) = two_stage(100.0, 1.0, 100.0, 1.0); // 100-chunk pipeline
    bench("expand+simulate 2x100 chunks", || {
        let ann = Annotations { pipelined: vec![a, f], ..Default::default() };
        simulate(&expand(&g, &ann), &cluster, &SimConfig::default()).unwrap();
    });
}
