//! Open-system streaming throughput — what the era-chained open-loop
//! driver (`sim/openloop.rs`) costs over the closed engine, and what a
//! loaded stream looks like under admission control. Three regimes per
//! workload size, all on the incremental-queue + component-allocation
//! corner:
//!
//! 1. **closed** — one closed run of the [`concat_jobs`] concatenation
//!    (the PR 8 cost profile; the baseline every open run is priced
//!    against),
//! 2. **open-t0** — the same jobs streamed through the driver with
//!    every arrival at `t = 0` and an infinite watermark: exactly one
//!    era, so the delta is pure driver overhead,
//! 3. **stream** — Poisson arrivals with a finite watermark and a
//!    deferral window: eras chain, deferred jobs retest at boundaries,
//!    overloaded arrivals are shed, and the JCT distribution +
//!    admitted/shed counters are reported.
//!
//! Oracles run on every invocation, before timing:
//!
//! * **closed-mode bit-identity** — open-at-t0 must match the closed
//!   run on every corner of the {queue} × {alloc} × {horizon} matrix ×
//!   threads ∈ {1, 4} × recovery ∈ {failfast, retry}: event counts,
//!   makespan and per-job traces bitwise on the eager corners, within
//!   the shared 1e-6 tolerance on anchored, and exactly one era.
//! * **stream determinism** — on every matrix corner × recovery
//!   policy, the loaded stream at threads 2 and 4 must reproduce the
//!   serial run bit for bit: the admitted/rejected set, every per-job
//!   outcome, admission instants, JCTs, events and eras (thread count
//!   shards the refill, never the semantics).
//!
//! `BENCH_SMOKE=1` (the CI bench-smoke job) shrinks sizes and still
//! runs every oracle. Results are printed as tables (README
//! §Performance) and persisted to `BENCH_sim.json` (section
//! `open_sweep`) for cross-PR tracking.

use std::time::Instant;

use mxdag::sim::{
    concat_jobs, expand, poisson_arrivals, run_open, simulate, within_tolerance, AllocKind,
    Cluster, HorizonKind, OpenConfig, OpenJob, OpenResult, QueueKind, RecoveryPolicy, SimConfig,
};
use mxdag::util::bench::{write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::workloads::{random_dag, RandomParams};

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// (jobs in the stream, layers, width) per sweep row.
fn shapes() -> Vec<(usize, usize, usize)> {
    if smoke() {
        vec![(4, 3, 3)]
    } else {
        vec![(8, 6, 6), (12, 8, 8)]
    }
}

/// Best-of-`reps` wall time for `f` (which must be pure).
fn timed<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

const MATRIX: [(QueueKind, AllocKind, HorizonKind); 8] = [
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
];

fn corner_cfg(
    (queue, alloc, horizon): (QueueKind, AllocKind, HorizonKind),
    threads: usize,
    recovery: RecoveryPolicy,
) -> SimConfig {
    SimConfig { queue, alloc, horizon, threads, recovery, ..Default::default() }
}

/// The closed-mode oracle (untimed): with every arrival at `t = 0` and
/// an infinite watermark the driver must collapse to one era that is
/// the closed run of the concatenated DAG — on every engine corner ×
/// thread count × recovery policy.
fn closed_mode_oracle(jobs_t0: &[OpenJob], cluster: &Cluster) {
    let concat = concat_jobs(jobs_t0);
    for &corner in MATRIX.iter() {
        for threads in [1usize, 4] {
            for recovery in [
                RecoveryPolicy::FailFast,
                RecoveryPolicy::Retry { max_attempts: 3, backoff: 0.05 },
            ] {
                let cfg = corner_cfg(corner, threads, recovery);
                let closed = simulate(&concat, cluster, &cfg).expect("closed run completes");
                let open = run_open(
                    jobs_t0,
                    cluster,
                    &OpenConfig { engine: cfg, ..OpenConfig::default() },
                )
                .expect("open-at-t0 run completes");
                let tag = format!("{corner:?} t{threads} {}", recovery.label());
                assert_eq!(open.eras, 1, "{tag}: all-at-t0 must be a single era");
                assert_eq!(closed.events, open.events, "{tag}: event count");
                let mut base = 0usize;
                match corner.2 {
                    HorizonKind::Eager => {
                        assert_eq!(
                            closed.makespan.to_bits(),
                            open.makespan.to_bits(),
                            "{tag}: makespan"
                        );
                        for (j, jr) in open.jobs.iter().enumerate() {
                            for (k, t) in jr.trace.iter().enumerate() {
                                let c = &closed.trace[base + k];
                                assert_eq!(c.start.to_bits(), t.start.to_bits(), "{tag}: j{j} c{k}");
                                assert_eq!(
                                    c.finish.to_bits(),
                                    t.finish.to_bits(),
                                    "{tag}: j{j} c{k}"
                                );
                            }
                            base += jr.trace.len();
                        }
                    }
                    HorizonKind::Anchored => {
                        assert!(
                            within_tolerance(closed.makespan, open.makespan),
                            "{tag}: makespan {} vs {}",
                            closed.makespan,
                            open.makespan
                        );
                        let ok =
                            |x: f64, y: f64| within_tolerance(x, y) || (x.is_nan() && y.is_nan());
                        for (j, jr) in open.jobs.iter().enumerate() {
                            for (k, t) in jr.trace.iter().enumerate() {
                                let c = &closed.trace[base + k];
                                assert!(
                                    ok(c.start, t.start) && ok(c.finish, t.finish),
                                    "{tag}: j{j} c{k}"
                                );
                            }
                            base += jr.trace.len();
                        }
                    }
                }
            }
        }
    }
}

/// The stream-determinism oracle (untimed): the loaded stream rerun at
/// threads 2 and 4 must reproduce the serial run bit for bit on every
/// corner × recovery policy — same admitted/rejected set, same per-job
/// outcomes, same admission instants and JCTs.
fn stream_determinism_oracle(jobs: &[OpenJob], cluster: &Cluster, watermark: f64, defer_max: f64) {
    for &corner in MATRIX.iter() {
        for recovery in [
            RecoveryPolicy::FailFast,
            RecoveryPolicy::Retry { max_attempts: 3, backoff: 0.05 },
        ] {
            let run_at = |threads| {
                run_open(
                    jobs,
                    cluster,
                    &OpenConfig {
                        watermark,
                        defer_max,
                        engine: corner_cfg(corner, threads, recovery),
                    },
                )
                .expect("stream run completes")
            };
            let base = run_at(1);
            for threads in [2usize, 4] {
                let r = run_at(threads);
                let tag = format!("stream {corner:?} t{threads} {}", recovery.label());
                assert_eq!(base.eras, r.eras, "{tag}: eras");
                assert_eq!(base.events, r.events, "{tag}: events");
                assert_eq!(base.admitted, r.admitted, "{tag}: admitted");
                assert_eq!(base.rejected, r.rejected, "{tag}: rejected");
                assert_eq!(base.makespan.to_bits(), r.makespan.to_bits(), "{tag}: makespan");
                for (j, (a, b)) in base.jobs.iter().zip(r.jobs.iter()).enumerate() {
                    assert_eq!(
                        a.admitted_at.map(f64::to_bits),
                        b.admitted_at.map(f64::to_bits),
                        "{tag}: job {j} admission instant"
                    );
                    assert_eq!(
                        a.jct.map(f64::to_bits),
                        b.jct.map(f64::to_bits),
                        "{tag}: job {j} jct"
                    );
                    assert_eq!(
                        std::mem::discriminant(&a.outcome),
                        std::mem::discriminant(&b.outcome),
                        "{tag}: job {j} outcome {:?} vs {:?}",
                        a.outcome,
                        b.outcome
                    );
                }
            }
        }
    }
}

fn open_sweep() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "open sweep events/s (closed baseline vs open-at-t0 vs loaded stream)",
        &[
            "jobs", "closed", "open-t0", "stream", "admitted", "shed", "jct p50", "jct p99",
            "open/closed",
        ],
    );
    let mut rows = Vec::new();
    for (n_jobs, layers, width) in shapes() {
        // one random DAG per job (distinct seeds), shared host pool
        let dags: Vec<_> = (0..n_jobs)
            .map(|j| {
                let p = RandomParams {
                    layers,
                    width,
                    hosts,
                    seed: 47 + j as u64,
                    ..Default::default()
                };
                expand(&random_dag(&p), &Default::default())
            })
            .collect();
        let fast = SimConfig {
            queue: QueueKind::Incremental,
            alloc: AllocKind::Components,
            ..Default::default()
        };
        // the solo makespan of the first job sizes arrival rate,
        // watermark, deferral window and deadline
        let solo = simulate(&dags[0], &cluster, &fast).expect("solo run").makespan;
        let arrivals = poisson_arrivals(0xD1CE, 2.0 / solo, n_jobs);
        let jobs_t0: Vec<OpenJob> = dags
            .iter()
            .map(|d| OpenJob { at: 0.0, dag: d.clone(), deadline: None, weight: 1 })
            .collect();
        let stream_jobs: Vec<OpenJob> = dags
            .iter()
            .zip(arrivals.iter())
            .map(|(d, &at)| OpenJob { at, dag: d.clone(), deadline: Some(solo * 4.0), weight: 1 })
            .collect();
        let watermark = solo * 1.5;
        let defer_max = solo * 0.5;

        // -- oracles first (untimed)
        closed_mode_oracle(&jobs_t0, &cluster);
        stream_determinism_oracle(&stream_jobs, &cluster, watermark, defer_max);

        // -- timings
        let reps = if smoke() { 1 } else { 3 };
        let concat = concat_jobs(&jobs_t0);
        let open_t0_cfg = OpenConfig { engine: fast.clone(), ..OpenConfig::default() };
        let stream_cfg =
            OpenConfig { watermark, defer_max, engine: fast.clone() };
        let r_closed = simulate(&concat, &cluster, &fast).expect("closed run");
        let r_t0 = run_open(&jobs_t0, &cluster, &open_t0_cfg).expect("open-t0 run");
        let r_stream: OpenResult =
            run_open(&stream_jobs, &cluster, &stream_cfg).expect("stream run");
        let t_closed = timed(reps, || {
            std::hint::black_box(simulate(&concat, &cluster, &fast).unwrap().makespan);
        });
        let t_t0 = timed(reps, || {
            std::hint::black_box(run_open(&jobs_t0, &cluster, &open_t0_cfg).unwrap().makespan);
        });
        let t_stream = timed(reps, || {
            std::hint::black_box(
                run_open(&stream_jobs, &cluster, &stream_cfg).unwrap().makespan,
            );
        });
        let evps_closed = r_closed.events as f64 / t_closed;
        let evps_t0 = r_t0.events as f64 / t_t0;
        let evps_stream = r_stream.events as f64 / t_stream;
        let p50 = r_stream.jct_percentile(0.5).unwrap_or(f64::NAN);
        let p99 = r_stream.jct_percentile(0.99).unwrap_or(f64::NAN);
        table.row(
            &format!("{n_jobs} x {} tasks", dags[0].len()),
            &[
                format!("{n_jobs}"),
                format!("{evps_closed:.0}"),
                format!("{evps_t0:.0}"),
                format!("{evps_stream:.0}"),
                format!("{}", r_stream.admitted),
                format!("{}", r_stream.rejected),
                format!("{p50:.3}"),
                format!("{p99:.3}"),
                format!("{:.2}x", t_t0 / t_closed),
            ],
        );
        rows.push(Json::obj(vec![
            ("jobs", Json::Num(n_jobs as f64)),
            ("tasks_per_job", Json::Num(dags[0].len() as f64)),
            ("events_closed", Json::Num(r_closed.events as f64)),
            ("events_open_t0", Json::Num(r_t0.events as f64)),
            ("events_stream", Json::Num(r_stream.events as f64)),
            ("eras_stream", Json::Num(r_stream.eras as f64)),
            ("admitted", Json::Num(r_stream.admitted as f64)),
            ("shed", Json::Num(r_stream.rejected as f64)),
            ("completed", Json::Num(r_stream.completed as f64)),
            ("jct_p50", Json::Num(p50)),
            ("jct_p99", Json::Num(p99)),
            (
                "deadline_hit_rate",
                r_stream.deadline_hit_rate().map(Json::Num).unwrap_or(Json::Null),
            ),
            ("events_per_sec_closed", Json::Num(evps_closed)),
            ("events_per_sec_open_t0", Json::Num(evps_t0)),
            ("events_per_sec_stream", Json::Num(evps_stream)),
            ("overhead_open_t0_vs_closed", Json::Num(t_t0 / t_closed)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn main() {
    println!("== closed-mode bit-identity + stream-determinism oracles run before every timing ==");
    let rows = open_sweep();
    write_bench_json(
        "open_sweep",
        Json::obj(vec![("smoke", Json::Bool(smoke())), ("rows", rows)]),
    );
    println!("\nwrote BENCH_sim.json (section `open_sweep`)");
}
