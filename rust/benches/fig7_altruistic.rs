//! Fig. 7 — altruistic multi-MXDAG scheduling (Principle 2): job 1
//! delays its non-critical b/f2 to LST; job 2's critical path gets the
//! freed resources (T1 < T2) while job 1's JCT is unchanged.

use mxdag::sched::altruistic::{merge, AltruisticScheduler, SelfishScheduler};
use mxdag::sched::evaluate;
use mxdag::sim::Cluster;
use mxdag::util::bench::Table;
use mxdag::workloads::{fig7_jobs, mapreduce_dag, MapReduceParams};

fn main() {
    // the exact Fig. 7 instance
    let (j1, j2) = fig7_jobs();
    let multi = merge(&[j1, j2]);
    let cluster = Cluster::uniform(4);
    let selfish = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi)).unwrap();
    let altru = evaluate(&multi.dag, &cluster, &AltruisticScheduler.plan_multi_checked(&multi, &cluster)).unwrap();

    let mut t = Table::new("Fig 7 — two map-reduce jobs", &["job1 JCT", "job2 JCT"]);
    t.row_f64("selfish (Fig 7c)", &[multi.jct(0, &selfish), multi.jct(1, &selfish)]);
    t.row_f64("altruistic (Fig 7d)", &[multi.jct(0, &altru), multi.jct(1, &altru)]);
    t.print();
    assert!(multi.jct(1, &altru) < multi.jct(1, &selfish), "T1 < T2");
    assert!(multi.jct(0, &altru) <= multi.jct(0, &selfish) + 1e-9, "job1 unharmed");

    // generalisation: random 2-job contention, sweep job-2 scale
    let mut t = Table::new(
        "generalised: job2 JCT under contention",
        &["selfish", "altruistic", "improvement %"],
    );
    for seed in 0..5u64 {
        let a = mapreduce_dag(&MapReduceParams {
            mappers: 3,
            reducers: 1,
            map_hosts: vec![0, 1],
            red_hosts: vec![2],
            map_time: 2.0,
            shuffle: 1.0,
            jitter: 0.3,
            seed,
            ..Default::default()
        })
        .0;
        let b = mapreduce_dag(&MapReduceParams {
            mappers: 2,
            reducers: 1,
            map_hosts: vec![1],
            red_hosts: vec![3],
            map_time: 1.0,
            shuffle: 0.5,
            jitter: 0.3,
            seed: seed + 100,
            ..Default::default()
        })
        .0;
        let multi = merge(&[a, b]);
        let cluster = Cluster::uniform(4);
        let s = evaluate(&multi.dag, &cluster, &SelfishScheduler.plan_multi(&multi)).unwrap();
        let al = evaluate(&multi.dag, &cluster, &AltruisticScheduler.plan_multi_checked(&multi, &cluster)).unwrap();
        let (s2, a2) = (multi.jct(1, &s), multi.jct(1, &al));
        t.row_f64(&format!("seed {seed}"), &[s2, a2, 100.0 * (s2 - a2) / s2]);
    }
    t.print();
}
