//! Scheduler scaling, three stories:
//!
//! 1. *Plan cost* vs DAG size, per scheduler — plan time must stay far
//!    below simulated makespan for online use (L3 §Perf).
//! 2. *Engine events/s* on wide-fanout DAGs at 1k / 5k / 10k tasks under
//!    the mxdag co-scheduler's priority plan: the pre-refactor full
//!    re-sort baseline vs the incremental ready queue (PR 2) vs
//!    component-wise allocation with memoized rates on top of it.
//! 3. The same A/B under the **fair** policy, where every ready task
//!    shares one level and whole-set allocation is costliest — the
//!    headline for `AllocKind::Components`.
//!
//! Every A/B asserts *bit-identical* results (event counts, makespans)
//! across configurations — the equivalence-oracle contract — and a
//! five-policy identity check runs all scheduler families through
//! `AllocKind::WholeSet` vs `AllocKind::Components`, comparing traces
//! bit for bit. Results are printed as tables (README §Performance) and
//! persisted to `BENCH_sim.json` for cross-PR tracking.
//!
//! `BENCH_SMOKE=1` shrinks everything to one small size and skips the
//! plan-cost story — the CI bench-smoke job uses it to catch oracle
//! drift and bench bitrot without paying full-scale runtimes.

use std::time::Instant;

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Scheduler,
};
use mxdag::sim::{
    expand, simulate, AllocKind, Cluster, Policy, QueueKind, SimConfig, SimDag, SimResult,
};
use mxdag::util::bench::{bench, bench_header, write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::workloads::{branches_for_tasks, random_dag, wide_fanout, FanoutParams, RandomParams};

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn sizes() -> Vec<usize> {
    if smoke() {
        vec![300]
    } else {
        vec![1_000, 5_000, 10_000]
    }
}

fn plan_cost() {
    for (layers, width) in [(6usize, 6usize), (12, 12), (20, 20)] {
        let p = RandomParams { layers, width, hosts: 16, seed: 3, ..Default::default() };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(16);
        bench_header(&format!(
            "plan cost on {} tasks ({} edges)",
            g.real_tasks().count(),
            g.n_edges()
        ));
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FairScheduler),
            Box::new(FifoScheduler),
            Box::new(PackingScheduler),
            Box::new(CoflowScheduler::new(Grouping::ByDst)),
            Box::new(MxScheduler::without_pipelining()),
        ];
        for s in &schedulers {
            bench(s.name(), || {
                let _ = s.plan(&g, &cluster);
            });
        }
        // the full scheduler with what-if search (simulations inside)
        bench("mxdag+pipeline-search", || {
            let s = MxScheduler::default();
            let _ = s.plan(&g, &cluster);
        });
    }
}

/// Best-of-`reps` timed simulation; returns (result, events/s).
fn timed(sim: &SimDag, cluster: &Cluster, cfg: &SimConfig, reps: usize) -> (SimResult, f64) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = simulate(sim, cluster, cfg).expect("simulation completes");
        best = best.min(t0.elapsed().as_secs_f64());
        out = Some(r);
    }
    let r = out.unwrap();
    let evps = r.events as f64 / best;
    (r, evps)
}

fn assert_bit_identical(tag: &str, a: &SimResult, b: &SimResult) {
    assert_eq!(a.events, b.events, "{tag}: configurations took different event paths");
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{tag}: makespans diverge ({} vs {})",
        a.makespan,
        b.makespan
    );
}

fn engine_events_per_sec() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "engine events/s, mxdag priority plan on wide-fanout DAGs \
         (full re-sort vs incremental queue vs component-wise alloc)",
        &["events", "full-resort ev/s", "incremental ev/s", "components ev/s", "speedup"],
    );
    let mut rows = Vec::new();
    for target in sizes() {
        let p = FanoutParams {
            branches: branches_for_tasks(target),
            hosts,
            seed: 42,
            ..Default::default()
        };
        let g = wide_fanout(&p);
        let plan = MxScheduler::without_pipelining().plan(&g, &cluster);
        // the point of the A/B is the priority hot path; the co-scheduler
        // must not have fallen back to its fair plan on this workload
        // (at smoke scale the what-if comparison may legitimately differ)
        if !smoke() {
            assert_eq!(plan.policy, Policy::priority(), "expected the priority plan");
        }
        let sim = expand(&g, &plan.ann);

        let configs = [
            (QueueKind::FullResort, AllocKind::WholeSet),
            (QueueKind::Incremental, AllocKind::WholeSet),
            (QueueKind::Incremental, AllocKind::Components),
        ];
        let mut results: Vec<(SimResult, f64)> = Vec::new();
        for (queue, alloc) in configs {
            let cfg = SimConfig { policy: plan.policy, queue, alloc, ..Default::default() };
            // the whole-set paths are slow at scale: one rep there,
            // best-of-3 for the cheap runs
            let reps = if alloc == AllocKind::WholeSet && target >= 5_000 { 1 } else { 3 };
            results.push(timed(&sim, &cluster, &cfg, reps));
        }
        for (tag, r) in [("incremental", &results[1].0), ("components", &results[2].0)] {
            assert_bit_identical(tag, &results[0].0, r);
        }
        let tasks = g.real_tasks().count();
        table.row(
            &format!("{tasks} tasks"),
            &[
                format!("{}", results[0].0.events),
                format!("{:.3e}", results[0].1),
                format!("{:.3e}", results[1].1),
                format!("{:.3e}", results[2].1),
                format!("{:.1}x", results[2].1 / results[1].1),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(tasks as f64)),
            ("events", Json::Num(results[0].0.events as f64)),
            ("evps_fullresort_wholeset", Json::Num(results[0].1)),
            ("evps_incremental_wholeset", Json::Num(results[1].1)),
            ("evps_incremental_components", Json::Num(results[2].1)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn fair_events_per_sec() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "engine events/s, fair policy on wide-fanout DAGs \
         (whole-set alloc = PR 2 incremental-queue baseline vs component-wise)",
        &["events", "whole-set ev/s", "components ev/s", "speedup"],
    );
    let mut rows = Vec::new();
    for target in sizes() {
        let p = FanoutParams {
            branches: branches_for_tasks(target),
            hosts,
            seed: 7,
            ..Default::default()
        };
        let g = wide_fanout(&p);
        let plan = FairScheduler.plan(&g, &cluster);
        assert_eq!(plan.policy, Policy::fair());
        let sim = expand(&g, &plan.ann);

        let mk = |alloc| SimConfig {
            policy: plan.policy,
            queue: QueueKind::Incremental,
            alloc,
            ..Default::default()
        };
        let reps_whole = if target >= 5_000 { 1 } else { 3 };
        let (whole, evps_whole) = timed(&sim, &cluster, &mk(AllocKind::WholeSet), reps_whole);
        let (comp, evps_comp) = timed(&sim, &cluster, &mk(AllocKind::Components), 3);
        assert_bit_identical("fair", &whole, &comp);

        let tasks = g.real_tasks().count();
        let speedup = evps_comp / evps_whole;
        table.row(
            &format!("{tasks} tasks"),
            &[
                format!("{}", whole.events),
                format!("{evps_whole:.3e}"),
                format!("{evps_comp:.3e}"),
                format!("{speedup:.1}x"),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(tasks as f64)),
            ("events", Json::Num(whole.events as f64)),
            ("evps_wholeset", Json::Num(evps_whole)),
            ("evps_components", Json::Num(evps_comp)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

/// All five policy families must produce bit-identical simulations under
/// `AllocKind::WholeSet` and `AllocKind::Components` — event counts,
/// makespans *and* per-chunk traces. This is the oracle pairing the
/// component layer is allowed to exist under.
fn policy_identity() {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let target = if smoke() { 300 } else { 1_200 };
    let p = FanoutParams {
        branches: branches_for_tasks(target),
        hosts,
        seed: 11,
        ..Default::default()
    };
    let g = wide_fanout(&p);
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FairScheduler),
        Box::new(FifoScheduler),
        Box::new(PackingScheduler),
        Box::new(CoflowScheduler::new(Grouping::ByDst)),
        Box::new(MxScheduler::without_pipelining()),
    ];
    for s in &schedulers {
        let plan = s.plan(&g, &cluster);
        let sim = expand(&g, &plan.ann);
        let mk = |alloc| SimConfig { policy: plan.policy, alloc, ..Default::default() };
        let whole = simulate(&sim, &cluster, &mk(AllocKind::WholeSet)).unwrap();
        let comp = simulate(&sim, &cluster, &mk(AllocKind::Components)).unwrap();
        assert_bit_identical(s.name(), &whole, &comp);
        for (i, (a, b)) in whole.trace.iter().zip(comp.trace.iter()).enumerate() {
            assert_eq!(
                a.start.to_bits(),
                b.start.to_bits(),
                "{}: chunk {i} start {} vs {}",
                s.name(),
                a.start,
                b.start
            );
            assert_eq!(
                a.finish.to_bits(),
                b.finish.to_bits(),
                "{}: chunk {i} finish {} vs {}",
                s.name(),
                a.finish,
                b.finish
            );
        }
        println!(
            "identity ok: {:<12} {} events, makespan {:.4}",
            s.name(),
            whole.events,
            whole.makespan
        );
    }
}

fn main() {
    if !smoke() {
        plan_cost();
    }
    println!("\n== alloc-kind identity, all five policies ==");
    policy_identity();
    let mxsched = engine_events_per_sec();
    let fair = fair_events_per_sec();
    write_bench_json(
        "sched_scaling",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke())),
            ("mxsched_priority", mxsched),
            ("fair", fair),
        ]),
    );
    println!("\nwrote BENCH_sim.json (section `sched_scaling`)");
}
