//! Scheduler scaling, three stories:
//!
//! 1. *Plan cost* vs DAG size, per scheduler — plan time must stay far
//!    below simulated makespan for online use (L3 §Perf).
//! 2. *Engine events/s* on wide-fanout DAGs at 1k / 5k / 10k tasks under
//!    the mxdag co-scheduler's priority plan: the pre-refactor full
//!    re-sort baseline vs the incremental ready queue (PR 2) vs
//!    component-wise allocation with memoized rates (PR 3) vs anchored
//!    time advance over the finish-time heap (PR 4) on top of it.
//! 3. The same A/B under the **fair** policy, where every ready task
//!    shares one level, whole-set allocation is costliest and the eager
//!    integration sweep touches every rated task — the headline for
//!    `AllocKind::Components` + `HorizonKind::Anchored`.
//!
//! Every eager-horizon A/B asserts *bit-identical* results (event
//! counts, makespans) across configurations — the equivalence-oracle
//! contract — while the anchored rows are held to the documented
//! **tolerance oracle** (makespan and per-chunk traces within 1e-6
//! relative of eager; anchored arithmetic is deliberately not
//! bit-identical). A five-policy identity check runs all scheduler
//! families through every corner of the {queue} × {alloc} × {horizon}
//! matrix. Results are printed as tables (README §Performance) and
//! persisted to `BENCH_sim.json` for cross-PR tracking.
//!
//! `BENCH_SMOKE=1` shrinks everything to one small size and skips the
//! plan-cost story — the CI bench-smoke job uses it to catch oracle
//! drift and bench bitrot (in both horizon modes) without paying
//! full-scale runtimes.

use std::time::Instant;

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Scheduler,
};
use mxdag::sim::{
    expand, simulate, within_tolerance, AllocKind, Cluster, HorizonKind, Policy, QueueKind,
    SimConfig, SimDag, SimResult,
};
use mxdag::util::bench::{bench, bench_header, write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::workloads::{branches_for_tasks, random_dag, wide_fanout, FanoutParams, RandomParams};

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn sizes() -> Vec<usize> {
    if smoke() {
        vec![300]
    } else {
        vec![1_000, 5_000, 10_000]
    }
}

fn plan_cost() {
    for (layers, width) in [(6usize, 6usize), (12, 12), (20, 20)] {
        let p = RandomParams { layers, width, hosts: 16, seed: 3, ..Default::default() };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(16);
        bench_header(&format!(
            "plan cost on {} tasks ({} edges)",
            g.real_tasks().count(),
            g.n_edges()
        ));
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FairScheduler),
            Box::new(FifoScheduler),
            Box::new(PackingScheduler),
            Box::new(CoflowScheduler::new(Grouping::ByDst)),
            Box::new(MxScheduler::without_pipelining()),
        ];
        for s in &schedulers {
            bench(s.name(), || {
                let _ = s.plan(&g, &cluster);
            });
        }
        // the full scheduler with what-if search (simulations inside)
        bench("mxdag+pipeline-search", || {
            let s = MxScheduler::default();
            let _ = s.plan(&g, &cluster);
        });
    }
}

/// Best-of-`reps` timed simulation; returns (result, events/s).
fn timed(sim: &SimDag, cluster: &Cluster, cfg: &SimConfig, reps: usize) -> (SimResult, f64) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = simulate(sim, cluster, cfg).expect("simulation completes");
        best = best.min(t0.elapsed().as_secs_f64());
        out = Some(r);
    }
    let r = out.unwrap();
    let evps = r.events as f64 / best;
    (r, evps)
}

fn assert_bit_identical(tag: &str, a: &SimResult, b: &SimResult) {
    assert_eq!(a.events, b.events, "{tag}: configurations took different event paths");
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{tag}: makespans diverge ({} vs {})",
        a.makespan,
        b.makespan
    );
}

/// The cross-horizon tolerance oracle (`mxdag::sim::within_tolerance`,
/// one definition for every oracle site): anchored results must match
/// the eager baseline on the makespan and every per-chunk trace time
/// (event counts may differ — anchored groups same-instant completions
/// by predicted finish, not by byte epsilon).
fn assert_within_tolerance(tag: &str, eager: &SimResult, anchored: &SimResult) {
    let close = within_tolerance;
    assert!(
        close(eager.makespan, anchored.makespan),
        "{tag}: makespans diverge beyond tolerance ({} vs {})",
        eager.makespan,
        anchored.makespan
    );
    assert_eq!(eager.trace.len(), anchored.trace.len(), "{tag}: trace length");
    for (i, (a, b)) in eager.trace.iter().zip(anchored.trace.iter()).enumerate() {
        assert!(
            close(a.start, b.start) && close(a.finish, b.finish),
            "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
            a.start,
            a.finish,
            b.start,
            b.finish
        );
    }
}

fn engine_events_per_sec() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "engine events/s, mxdag priority plan on wide-fanout DAGs \
         (full re-sort vs incremental queue vs component-wise alloc vs anchored horizon)",
        &[
            "events",
            "full-resort ev/s",
            "incremental ev/s",
            "components ev/s",
            "anchored ev/s",
            "anch/eager",
        ],
    );
    let mut rows = Vec::new();
    for target in sizes() {
        let p = FanoutParams {
            branches: branches_for_tasks(target),
            hosts,
            seed: 42,
            ..Default::default()
        };
        let g = wide_fanout(&p);
        let plan = MxScheduler::without_pipelining().plan(&g, &cluster);
        // the point of the A/B is the priority hot path; the co-scheduler
        // must not have fallen back to its fair plan on this workload
        // (at smoke scale the what-if comparison may legitimately differ)
        if !smoke() {
            assert_eq!(plan.policy, Policy::priority(), "expected the priority plan");
        }
        let sim = expand(&g, &plan.ann);

        let configs = [
            (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
            (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
            (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
            (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
        ];
        let mut results: Vec<(SimResult, f64)> = Vec::new();
        for (queue, alloc, horizon) in configs {
            let cfg = SimConfig {
                policy: plan.policy,
                queue,
                alloc,
                horizon,
                ..Default::default()
            };
            // the whole-set paths are slow at scale: one rep there,
            // best-of-3 for the cheap runs
            let reps = if alloc == AllocKind::WholeSet && target >= 5_000 { 1 } else { 3 };
            results.push(timed(&sim, &cluster, &cfg, reps));
        }
        // eager corners are bit-identical; the anchored corner is held
        // to the tolerance oracle against its eager twin
        for (tag, r) in [("incremental", &results[1].0), ("components", &results[2].0)] {
            assert_bit_identical(tag, &results[0].0, r);
        }
        assert_within_tolerance("anchored", &results[2].0, &results[3].0);
        let tasks = g.real_tasks().count();
        let anch_speedup = results[3].1 / results[2].1;
        table.row(
            &format!("{tasks} tasks"),
            &[
                format!("{}", results[0].0.events),
                format!("{:.3e}", results[0].1),
                format!("{:.3e}", results[1].1),
                format!("{:.3e}", results[2].1),
                format!("{:.3e}", results[3].1),
                format!("{anch_speedup:.1}x"),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(tasks as f64)),
            ("events", Json::Num(results[0].0.events as f64)),
            ("evps_fullresort_wholeset", Json::Num(results[0].1)),
            ("evps_incremental_wholeset", Json::Num(results[1].1)),
            ("evps_incremental_components", Json::Num(results[2].1)),
            ("evps_incremental_components_anchored", Json::Num(results[3].1)),
            ("speedup_anchored_vs_eager", Json::Num(anch_speedup)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn fair_events_per_sec() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "engine events/s, fair policy on wide-fanout DAGs \
         (whole-set alloc = PR 2 baseline vs component-wise vs anchored horizon)",
        &["events", "whole-set ev/s", "components ev/s", "anchored ev/s", "anch/eager"],
    );
    let mut rows = Vec::new();
    for target in sizes() {
        let p = FanoutParams {
            branches: branches_for_tasks(target),
            hosts,
            seed: 7,
            ..Default::default()
        };
        let g = wide_fanout(&p);
        let plan = FairScheduler.plan(&g, &cluster);
        assert_eq!(plan.policy, Policy::fair());
        let sim = expand(&g, &plan.ann);

        let mk = |alloc, horizon| SimConfig {
            policy: plan.policy,
            queue: QueueKind::Incremental,
            alloc,
            horizon,
            ..Default::default()
        };
        let reps_whole = if target >= 5_000 { 1 } else { 3 };
        let (whole, evps_whole) =
            timed(&sim, &cluster, &mk(AllocKind::WholeSet, HorizonKind::Eager), reps_whole);
        let (comp, evps_comp) =
            timed(&sim, &cluster, &mk(AllocKind::Components, HorizonKind::Eager), 3);
        let (anch, evps_anch) =
            timed(&sim, &cluster, &mk(AllocKind::Components, HorizonKind::Anchored), 3);
        assert_bit_identical("fair", &whole, &comp);
        assert_within_tolerance("fair-anchored", &comp, &anch);

        let tasks = g.real_tasks().count();
        let anch_speedup = evps_anch / evps_comp;
        table.row(
            &format!("{tasks} tasks"),
            &[
                format!("{}", whole.events),
                format!("{evps_whole:.3e}"),
                format!("{evps_comp:.3e}"),
                format!("{evps_anch:.3e}"),
                format!("{anch_speedup:.1}x"),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(tasks as f64)),
            ("events", Json::Num(whole.events as f64)),
            ("evps_wholeset", Json::Num(evps_whole)),
            ("evps_components", Json::Num(evps_comp)),
            ("evps_components_anchored", Json::Num(evps_anch)),
            ("speedup_components_vs_wholeset", Json::Num(evps_comp / evps_whole)),
            ("speedup_anchored_vs_eager", Json::Num(anch_speedup)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

/// All five policy families through every corner of the
/// {queue} × {alloc} × {horizon} matrix. The four eager corners must be
/// bit-identical — event counts, makespans *and* per-chunk traces (the
/// oracle pairing the component layer is allowed to exist under); the
/// four anchored corners must match the eager baseline within the 1e-6
/// relative tolerance oracle (the pairing the anchored horizon is
/// allowed to exist under).
fn policy_identity() {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let target = if smoke() { 300 } else { 1_200 };
    let p = FanoutParams {
        branches: branches_for_tasks(target),
        hosts,
        seed: 11,
        ..Default::default()
    };
    let g = wide_fanout(&p);
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FairScheduler),
        Box::new(FifoScheduler),
        Box::new(PackingScheduler),
        Box::new(CoflowScheduler::new(Grouping::ByDst)),
        Box::new(MxScheduler::without_pipelining()),
    ];
    let queues = [QueueKind::FullResort, QueueKind::Incremental];
    let allocs = [AllocKind::WholeSet, AllocKind::Components];
    for s in &schedulers {
        let plan = s.plan(&g, &cluster);
        let sim = expand(&g, &plan.ann);
        let mk = |queue, alloc, horizon| SimConfig {
            policy: plan.policy,
            queue,
            alloc,
            horizon,
            ..Default::default()
        };
        let base = simulate(
            &sim,
            &cluster,
            &mk(QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
        )
        .unwrap();
        for queue in queues {
            for alloc in allocs {
                let tag = format!("{} [{queue:?}/{alloc:?}]", s.name());
                let eager =
                    simulate(&sim, &cluster, &mk(queue, alloc, HorizonKind::Eager)).unwrap();
                assert_bit_identical(&tag, &base, &eager);
                for (i, (a, b)) in base.trace.iter().zip(eager.trace.iter()).enumerate() {
                    assert_eq!(
                        a.start.to_bits(),
                        b.start.to_bits(),
                        "{tag}: chunk {i} start {} vs {}",
                        a.start,
                        b.start
                    );
                    assert_eq!(
                        a.finish.to_bits(),
                        b.finish.to_bits(),
                        "{tag}: chunk {i} finish {} vs {}",
                        a.finish,
                        b.finish
                    );
                }
                let anch =
                    simulate(&sim, &cluster, &mk(queue, alloc, HorizonKind::Anchored)).unwrap();
                assert_within_tolerance(&format!("{tag} anchored"), &base, &anch);
            }
        }
        println!(
            "identity ok: {:<12} {} events, makespan {:.4} (8 configurations)",
            s.name(),
            base.events,
            base.makespan
        );
    }
}

fn main() {
    if !smoke() {
        plan_cost();
    }
    println!("\n== {{queue}} x {{alloc}} x {{horizon}} identity, all five policies ==");
    policy_identity();
    let mxsched = engine_events_per_sec();
    let fair = fair_events_per_sec();
    write_bench_json(
        "sched_scaling",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke())),
            ("mxsched_priority", mxsched),
            ("fair", fair),
        ]),
    );
    println!("\nwrote BENCH_sim.json (section `sched_scaling`)");
}
