//! Scheduler scaling, two stories:
//!
//! 1. *Plan cost* vs DAG size, per scheduler — plan time must stay far
//!    below simulated makespan for online use (L3 §Perf).
//! 2. *Engine events/s* on wide-fanout DAGs at 1k / 5k / 10k tasks under
//!    the mxdag co-scheduler's priority plan: the incremental ready
//!    queue (`QueueKind::Incremental`) vs the pre-refactor full
//!    re-sort baseline (`QueueKind::FullResort`). Identical results
//!    (event counts and makespans) are asserted on every run; only the
//!    per-event scheduling cost differs. This produces the events/s
//!    table whose format the README's Performance section describes —
//!    run `cargo bench --bench sched_scaling` to generate it.

use std::time::Instant;

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Scheduler,
};
use mxdag::sim::{expand, simulate, Cluster, Policy, QueueKind, SimConfig};
use mxdag::util::bench::{bench, bench_header, Table};
use mxdag::workloads::{branches_for_tasks, random_dag, wide_fanout, FanoutParams, RandomParams};

fn plan_cost() {
    for (layers, width) in [(6usize, 6usize), (12, 12), (20, 20)] {
        let p = RandomParams { layers, width, hosts: 16, seed: 3, ..Default::default() };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(16);
        bench_header(&format!(
            "plan cost on {} tasks ({} edges)",
            g.real_tasks().count(),
            g.n_edges()
        ));
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FairScheduler),
            Box::new(FifoScheduler),
            Box::new(PackingScheduler),
            Box::new(CoflowScheduler::new(Grouping::ByDst)),
            Box::new(MxScheduler::without_pipelining()),
        ];
        for s in &schedulers {
            bench(s.name(), || {
                let _ = s.plan(&g, &cluster);
            });
        }
        // the full scheduler with what-if search (simulations inside)
        bench("mxdag+pipeline-search", || {
            let s = MxScheduler::default();
            let _ = s.plan(&g, &cluster);
        });
    }
}

fn engine_events_per_sec() {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "engine events/s, mxdag priority plan on wide-fanout DAGs \
         (incremental ready queue vs full re-sort)",
        &["events", "full-resort ev/s", "incremental ev/s", "speedup"],
    );
    for target in [1_000usize, 5_000, 10_000] {
        let p = FanoutParams {
            branches: branches_for_tasks(target),
            hosts,
            seed: 42,
            ..Default::default()
        };
        let g = wide_fanout(&p);
        let plan = MxScheduler::without_pipelining().plan(&g, &cluster);
        // the point of the A/B is the priority hot path; the co-scheduler
        // must not have fallen back to its fair plan on this workload
        assert_eq!(plan.policy, Policy::priority(), "expected the priority plan");
        let sim = expand(&g, &plan.ann);

        let mut events = [0usize; 2];
        let mut makespans = [0.0f64; 2];
        let mut evs = [0.0f64; 2];
        for (ki, queue) in [QueueKind::FullResort, QueueKind::Incremental]
            .into_iter()
            .enumerate()
        {
            let cfg = SimConfig { policy: plan.policy, queue, ..Default::default() };
            // the baseline is slow at 10k tasks: one rep there, best-of-3
            // for the cheap runs
            let reps = if queue == QueueKind::FullResort && target >= 5_000 { 1 } else { 3 };
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t0 = Instant::now();
                let r = simulate(&sim, &cluster, &cfg).expect("simulation completes");
                best = best.min(t0.elapsed().as_secs_f64());
                events[ki] = r.events;
                makespans[ki] = r.makespan;
            }
            evs[ki] = events[ki] as f64 / best;
        }
        assert_eq!(events[0], events[1], "queue kinds took different event paths");
        assert!(
            (makespans[0] - makespans[1]).abs() < 1e-9,
            "queue kinds disagree: {} vs {}",
            makespans[0],
            makespans[1]
        );
        table.row(
            &format!("{} tasks", g.real_tasks().count()),
            &[
                format!("{}", events[0]),
                format!("{:.3e}", evs[0]),
                format!("{:.3e}", evs[1]),
                format!("{:.1}x", evs[1] / evs[0]),
            ],
        );
    }
    table.print();
}

fn main() {
    plan_cost();
    engine_events_per_sec();
}
