//! Scheduler planning cost vs DAG size, per scheduler — plan time must
//! stay far below simulated makespan for online use (L3 §Perf).

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Scheduler,
};
use mxdag::sim::Cluster;
use mxdag::util::bench::{bench, bench_header};
use mxdag::workloads::{random_dag, RandomParams};

fn main() {
    for (layers, width) in [(6usize, 6usize), (12, 12), (20, 20)] {
        let p = RandomParams { layers, width, hosts: 16, seed: 3, ..Default::default() };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(16);
        bench_header(&format!(
            "plan cost on {} tasks ({} edges)",
            g.real_tasks().count(),
            g.n_edges()
        ));
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FairScheduler),
            Box::new(FifoScheduler),
            Box::new(PackingScheduler),
            Box::new(CoflowScheduler::new(Grouping::ByDst)),
            Box::new(MxScheduler::without_pipelining()),
        ];
        for s in &schedulers {
            bench(s.name(), || {
                let _ = s.plan(&g, &cluster);
            });
        }
        // the full scheduler with what-if search (simulations inside)
        bench("mxdag+pipeline-search", || {
            let s = MxScheduler::default();
            let _ = s.plan(&g, &cluster);
        });
    }
}
