//! Scheduler scaling, four stories:
//!
//! 1. *Plan cost* vs DAG size, per scheduler — plan time must stay far
//!    below simulated makespan for online use (L3 §Perf).
//! 2. *Engine events/s* on wide-fanout DAGs at 1k / 5k / 10k / 100k
//!    tasks under the mxdag co-scheduler's priority plan: the
//!    pre-refactor full re-sort baseline vs the incremental ready queue
//!    (PR 2) vs component-wise allocation (PR 3) vs anchored time
//!    advance (PR 4). The O(n)-per-event whole-set baselines are only
//!    affordable up to 10k tasks; above that their columns are emitted
//!    as JSON `null` and the identity baseline shifts to the
//!    components-eager corner (itself transitively anchored to the
//!    whole-set oracle at the smaller sizes and in the prop tests).
//! 3. The same A/B under the **fair** policy, where every ready task
//!    shares one level, whole-set allocation is costliest and the eager
//!    integration sweep touches every rated task.
//! 4. *Parallel refill scaling* (PR 6): a lockstep parallel-fabrics
//!    workload — 128 independent host pairs completing in unison, so
//!    every event re-fills 256 members across 128 fresh components —
//!    timed at `threads` 1 / 2 / 4. Before any timing, a threads=4 run
//!    is asserted bit-identical to threads=1 under the eager horizon
//!    (and within tolerance under anchored): the bench-smoke
//!    parallel-identity oracle. `events_per_sec_per_core`
//!    (t4 events/s ÷ 4) is the headline tracked in `BENCH_sim.json`.
//!
//! Every eager-horizon A/B asserts *bit-identical* results (event
//! counts, makespans) across configurations — the equivalence-oracle
//! contract — while the anchored rows are held to the documented
//! **tolerance oracle** (makespan and per-chunk traces within 1e-6
//! relative of eager; anchored arithmetic is deliberately not
//! bit-identical). A five-policy identity check runs all scheduler
//! families through every corner of the {queue} × {alloc} × {horizon}
//! matrix. Results are printed as tables (README §Performance) and
//! persisted to `BENCH_sim.json` for cross-PR tracking.
//!
//! `BENCH_SMOKE=1` shrinks everything to one small size and skips the
//! plan-cost story — the CI bench-smoke job uses it to catch oracle
//! drift and bench bitrot (in both horizon modes, serial and parallel)
//! without paying full-scale runtimes. `MXDAG_BENCH_1M=1` appends a
//! 1M-task size to the non-smoke sweeps.

use std::time::Instant;

use mxdag::sched::{
    CoflowScheduler, FairScheduler, FifoScheduler, Grouping, MxScheduler, PackingScheduler,
    Scheduler,
};
use mxdag::sim::{
    expand, simulate, within_tolerance, AllocKind, Cluster, HorizonKind, Policy, QueueKind,
    SimConfig, SimDag, SimKind, SimResult, SimTask,
};
use mxdag::util::bench::{bench, bench_header, write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::workloads::{branches_for_tasks, random_dag, wide_fanout, FanoutParams, RandomParams};

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn sizes() -> Vec<usize> {
    if smoke() {
        return vec![300];
    }
    let mut s = vec![1_000, 5_000, 10_000, 100_000];
    if std::env::var("MXDAG_BENCH_1M").map(|v| v == "1").unwrap_or(false) {
        s.push(1_000_000);
    }
    s
}

/// The O(n)-per-event whole-set / full-resort baselines are only
/// affordable up to this size; beyond it their columns are emitted as
/// JSON `null` and identity is asserted against the components corner.
const FULL_MATRIX_MAX: usize = 10_000;

fn plan_cost() {
    for (layers, width) in [(6usize, 6usize), (12, 12), (20, 20)] {
        let p = RandomParams { layers, width, hosts: 16, seed: 3, ..Default::default() };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(16);
        bench_header(&format!(
            "plan cost on {} tasks ({} edges)",
            g.real_tasks().count(),
            g.n_edges()
        ));
        let schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(FairScheduler),
            Box::new(FifoScheduler),
            Box::new(PackingScheduler),
            Box::new(CoflowScheduler::new(Grouping::ByDst)),
            Box::new(MxScheduler::without_pipelining()),
        ];
        for s in &schedulers {
            bench(s.name(), || {
                let _ = s.plan(&g, &cluster);
            });
        }
        // the full scheduler with what-if search (simulations inside)
        bench("mxdag+pipeline-search", || {
            let s = MxScheduler::default();
            let _ = s.plan(&g, &cluster);
        });
    }
}

/// Best-of-`reps` timed simulation; returns (result, events/s).
fn timed(sim: &SimDag, cluster: &Cluster, cfg: &SimConfig, reps: usize) -> (SimResult, f64) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps {
        let t0 = Instant::now();
        let r = simulate(sim, cluster, cfg).expect("simulation completes");
        best = best.min(t0.elapsed().as_secs_f64());
        out = Some(r);
    }
    let r = out.unwrap();
    let evps = r.events as f64 / best;
    (r, evps)
}

fn assert_bit_identical(tag: &str, a: &SimResult, b: &SimResult) {
    assert_eq!(a.events, b.events, "{tag}: configurations took different event paths");
    assert_eq!(
        a.makespan.to_bits(),
        b.makespan.to_bits(),
        "{tag}: makespans diverge ({} vs {})",
        a.makespan,
        b.makespan
    );
}

/// The cross-horizon tolerance oracle (`mxdag::sim::within_tolerance`,
/// one definition for every oracle site): anchored results must match
/// the eager baseline on the makespan and every per-chunk trace time
/// (event counts may differ — anchored groups same-instant completions
/// by predicted finish, not by byte epsilon).
fn assert_within_tolerance(tag: &str, eager: &SimResult, anchored: &SimResult) {
    let close = within_tolerance;
    assert!(
        close(eager.makespan, anchored.makespan),
        "{tag}: makespans diverge beyond tolerance ({} vs {})",
        eager.makespan,
        anchored.makespan
    );
    assert_eq!(eager.trace.len(), anchored.trace.len(), "{tag}: trace length");
    for (i, (a, b)) in eager.trace.iter().zip(anchored.trace.iter()).enumerate() {
        assert!(
            close(a.start, b.start) && close(a.finish, b.finish),
            "{tag}: chunk {i} trace {:?}..{:?} vs {:?}..{:?}",
            a.start,
            a.finish,
            b.start,
            b.finish
        );
    }
}

fn engine_events_per_sec() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "engine events/s, mxdag priority plan on wide-fanout DAGs \
         (full re-sort vs incremental queue vs component-wise alloc vs anchored horizon)",
        &[
            "events",
            "full-resort ev/s",
            "incremental ev/s",
            "components ev/s",
            "anchored ev/s",
            "anch/eager",
        ],
    );
    let mut rows = Vec::new();
    for target in sizes() {
        let p = FanoutParams {
            branches: branches_for_tasks(target),
            hosts,
            seed: 42,
            ..Default::default()
        };
        let g = wide_fanout(&p);
        let plan = MxScheduler::without_pipelining().plan(&g, &cluster);
        // the point of the A/B is the priority hot path; the co-scheduler
        // must not have fallen back to its fair plan on this workload
        // (at smoke scale the what-if comparison may legitimately differ)
        if !smoke() {
            assert_eq!(plan.policy, Policy::priority(), "expected the priority plan");
        }
        let sim = expand(&g, &plan.ann);

        let mk = |queue, alloc, horizon| SimConfig {
            policy: plan.policy,
            queue,
            alloc,
            horizon,
            ..Default::default()
        };
        // the O(n)-per-event whole-set baselines are unaffordable past
        // FULL_MATRIX_MAX: skip them and emit `null` columns instead
        let full_matrix = target <= FULL_MATRIX_MAX;
        let reps_whole = if target >= 5_000 { 1 } else { 3 };
        let whole = full_matrix.then(|| {
            timed(
                &sim,
                &cluster,
                &mk(QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
                reps_whole,
            )
        });
        let incr = full_matrix.then(|| {
            timed(
                &sim,
                &cluster,
                &mk(QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
                reps_whole,
            )
        });
        let comp = timed(
            &sim,
            &cluster,
            &mk(QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
            3,
        );
        let anch = timed(
            &sim,
            &cluster,
            &mk(QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
            3,
        );
        // eager corners are bit-identical; the anchored corner is held
        // to the tolerance oracle against its eager twin
        if let (Some(w), Some(i)) = (&whole, &incr) {
            assert_bit_identical("incremental", &w.0, &i.0);
            assert_bit_identical("components", &w.0, &comp.0);
        }
        assert_within_tolerance("anchored", &comp.0, &anch.0);
        let tasks = g.real_tasks().count();
        let anch_speedup = anch.1 / comp.1;
        let fmt_opt =
            |r: &Option<(SimResult, f64)>| r.as_ref().map_or("-".into(), |x| format!("{:.3e}", x.1));
        table.row(
            &format!("{tasks} tasks"),
            &[
                format!("{}", comp.0.events),
                fmt_opt(&whole),
                fmt_opt(&incr),
                format!("{:.3e}", comp.1),
                format!("{:.3e}", anch.1),
                format!("{anch_speedup:.1}x"),
            ],
        );
        let json_opt = |r: &Option<(SimResult, f64)>| r.as_ref().map_or(Json::Null, |x| Json::Num(x.1));
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(tasks as f64)),
            ("events", Json::Num(comp.0.events as f64)),
            ("evps_fullresort_wholeset", json_opt(&whole)),
            ("evps_incremental_wholeset", json_opt(&incr)),
            ("evps_incremental_components", Json::Num(comp.1)),
            ("evps_incremental_components_anchored", Json::Num(anch.1)),
            ("speedup_anchored_vs_eager", Json::Num(anch_speedup)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn fair_events_per_sec() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "engine events/s, fair policy on wide-fanout DAGs \
         (whole-set alloc = PR 2 baseline vs component-wise vs anchored horizon)",
        &["events", "whole-set ev/s", "components ev/s", "anchored ev/s", "anch/eager"],
    );
    let mut rows = Vec::new();
    for target in sizes() {
        let p = FanoutParams {
            branches: branches_for_tasks(target),
            hosts,
            seed: 7,
            ..Default::default()
        };
        let g = wide_fanout(&p);
        let plan = FairScheduler.plan(&g, &cluster);
        assert_eq!(plan.policy, Policy::fair());
        let sim = expand(&g, &plan.ann);

        let mk = |alloc, horizon| SimConfig {
            policy: plan.policy,
            queue: QueueKind::Incremental,
            alloc,
            horizon,
            ..Default::default()
        };
        let full_matrix = target <= FULL_MATRIX_MAX;
        let reps_whole = if target >= 5_000 { 1 } else { 3 };
        let whole = full_matrix
            .then(|| timed(&sim, &cluster, &mk(AllocKind::WholeSet, HorizonKind::Eager), reps_whole));
        let (comp, evps_comp) =
            timed(&sim, &cluster, &mk(AllocKind::Components, HorizonKind::Eager), 3);
        let (anch, evps_anch) =
            timed(&sim, &cluster, &mk(AllocKind::Components, HorizonKind::Anchored), 3);
        if let Some((w, _)) = &whole {
            assert_bit_identical("fair", w, &comp);
        }
        assert_within_tolerance("fair-anchored", &comp, &anch);

        let tasks = g.real_tasks().count();
        let anch_speedup = evps_anch / evps_comp;
        table.row(
            &format!("{tasks} tasks"),
            &[
                format!("{}", comp.events),
                whole.as_ref().map_or("-".into(), |(_, e)| format!("{e:.3e}")),
                format!("{evps_comp:.3e}"),
                format!("{evps_anch:.3e}"),
                format!("{anch_speedup:.1}x"),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(tasks as f64)),
            ("events", Json::Num(comp.events as f64)),
            ("evps_wholeset", whole.as_ref().map_or(Json::Null, |(_, e)| Json::Num(*e))),
            ("evps_components", Json::Num(evps_comp)),
            ("evps_components_anchored", Json::Num(evps_anch)),
            (
                "speedup_components_vs_wholeset",
                whole.as_ref().map_or(Json::Null, |(_, e)| Json::Num(evps_comp / e)),
            ),
            ("speedup_anchored_vs_eager", Json::Num(anch_speedup)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

/// The parallel-refill showcase workload: `PAIRS` independent host
/// pairs, each running a lockstep chain of stages whose flow sizes
/// depend only on the stage index — every pair completes each stage at
/// the same instant, so every completion event drains and re-fills all
/// `PAIRS` components at once (`PAIRS × PER_STAGE` members, past the
/// engine's parallel fan-out threshold). This is the identical-
/// parallel-networks regime from the paper's related work: maximal
/// component concurrency, worst case for a serial refill loop.
fn lockstep_pairs_dag(stages: usize) -> (SimDag, Cluster) {
    const PAIRS: usize = 128;
    const PER_STAGE: usize = 2;
    let mut d = SimDag::default();
    let mut prev: Vec<Vec<usize>> = vec![Vec::new(); PAIRS];
    for s in 0..stages {
        // identical across pairs → lockstep completions
        let size = 1.0 + (s % 7) as f64 * 0.25;
        for pair in 0..PAIRS {
            let mut next = Vec::with_capacity(PER_STAGE);
            for _ in 0..PER_STAGE {
                let orig = d.len();
                let id = d.push(SimTask {
                    orig,
                    chunk: (0, 1),
                    kind: SimKind::Flow { src: 2 * pair, dst: 2 * pair + 1 },
                    size,
                    priority: 0,
                    gate: 0.0,
                    coflow: None,
                });
                for &g in prev[pair].iter() {
                    d.dep(g, id);
                }
                next.push(id);
            }
            prev[pair] = next;
        }
    }
    (d, Cluster::uniform(2 * PAIRS))
}

/// Story 4: the parallel event loop, `threads` 1 / 2 / 4 on the
/// lockstep workload. The identity oracle runs *before* any timing —
/// eager threads=4 bit-identical to threads=1 (makespan, events and
/// every trace float), anchored within tolerance — so a determinism
/// regression fails the bench (and the CI bench-smoke job) even when
/// nobody reads the numbers.
fn parallel_events_per_sec() -> Json {
    let mut table = Table::new(
        "parallel refill scaling, fair policy on 128 lockstep host pairs \
         (every event re-fills 256 members across 128 fresh components)",
        &["events", "t1 ev/s", "t2 ev/s", "t4 ev/s", "per-core t4", "t4/t1"],
    );
    let mut rows = Vec::new();
    for target in sizes() {
        let stages = (target / 256).max(2);
        let (d, cluster) = lockstep_pairs_dag(stages);
        let mk = |horizon, threads| SimConfig {
            policy: Policy::fair(),
            horizon,
            threads,
            ..Default::default()
        };
        // parallel-identity oracle (bench-smoke gate)
        let eager1 = simulate(&d, &cluster, &mk(HorizonKind::Eager, 1)).unwrap();
        let eager4 = simulate(&d, &cluster, &mk(HorizonKind::Eager, 4)).unwrap();
        assert_bit_identical("parallel-eager", &eager1, &eager4);
        for (i, (a, b)) in eager1.trace.iter().zip(eager4.trace.iter()).enumerate() {
            assert_eq!(a.start.to_bits(), b.start.to_bits(), "parallel-eager chunk {i} start");
            assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "parallel-eager chunk {i} finish");
        }
        let (anch1, evps1) = timed(&d, &cluster, &mk(HorizonKind::Anchored, 1), 3);
        let (anch2, evps2) = timed(&d, &cluster, &mk(HorizonKind::Anchored, 2), 3);
        let (anch4, evps4) = timed(&d, &cluster, &mk(HorizonKind::Anchored, 4), 3);
        assert_within_tolerance("parallel-anchored-t2", &anch1, &anch2);
        assert_within_tolerance("parallel-anchored-t4", &anch1, &anch4);
        let per_core = evps4 / 4.0;
        let speedup = evps4 / evps1;
        table.row(
            &format!("{} tasks", d.len()),
            &[
                format!("{}", anch1.events),
                format!("{evps1:.3e}"),
                format!("{evps2:.3e}"),
                format!("{evps4:.3e}"),
                format!("{per_core:.3e}"),
                format!("{speedup:.2}x"),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(d.len() as f64)),
            ("events", Json::Num(anch1.events as f64)),
            ("evps_threads1", Json::Num(evps1)),
            ("evps_parallel_t2", Json::Num(evps2)),
            ("evps_parallel_t4", Json::Num(evps4)),
            ("events_per_sec_per_core", Json::Num(per_core)),
            ("speedup_t4_vs_t1", Json::Num(speedup)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

/// All five policy families through every corner of the
/// {queue} × {alloc} × {horizon} matrix. The four eager corners must be
/// bit-identical — event counts, makespans *and* per-chunk traces (the
/// oracle pairing the component layer is allowed to exist under); the
/// four anchored corners must match the eager baseline within the 1e-6
/// relative tolerance oracle (the pairing the anchored horizon is
/// allowed to exist under).
fn policy_identity() {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let target = if smoke() { 300 } else { 1_200 };
    let p = FanoutParams {
        branches: branches_for_tasks(target),
        hosts,
        seed: 11,
        ..Default::default()
    };
    let g = wide_fanout(&p);
    let schedulers: Vec<Box<dyn Scheduler>> = vec![
        Box::new(FairScheduler),
        Box::new(FifoScheduler),
        Box::new(PackingScheduler),
        Box::new(CoflowScheduler::new(Grouping::ByDst)),
        Box::new(MxScheduler::without_pipelining()),
    ];
    let queues = [QueueKind::FullResort, QueueKind::Incremental];
    let allocs = [AllocKind::WholeSet, AllocKind::Components];
    for s in &schedulers {
        let plan = s.plan(&g, &cluster);
        let sim = expand(&g, &plan.ann);
        let mk = |queue, alloc, horizon| SimConfig {
            policy: plan.policy,
            queue,
            alloc,
            horizon,
            ..Default::default()
        };
        let base = simulate(
            &sim,
            &cluster,
            &mk(QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
        )
        .unwrap();
        for queue in queues {
            for alloc in allocs {
                let tag = format!("{} [{queue:?}/{alloc:?}]", s.name());
                let eager =
                    simulate(&sim, &cluster, &mk(queue, alloc, HorizonKind::Eager)).unwrap();
                assert_bit_identical(&tag, &base, &eager);
                for (i, (a, b)) in base.trace.iter().zip(eager.trace.iter()).enumerate() {
                    assert_eq!(
                        a.start.to_bits(),
                        b.start.to_bits(),
                        "{tag}: chunk {i} start {} vs {}",
                        a.start,
                        b.start
                    );
                    assert_eq!(
                        a.finish.to_bits(),
                        b.finish.to_bits(),
                        "{tag}: chunk {i} finish {} vs {}",
                        a.finish,
                        b.finish
                    );
                }
                let anch =
                    simulate(&sim, &cluster, &mk(queue, alloc, HorizonKind::Anchored)).unwrap();
                assert_within_tolerance(&format!("{tag} anchored"), &base, &anch);
            }
        }
        println!(
            "identity ok: {:<12} {} events, makespan {:.4} (8 configurations)",
            s.name(),
            base.events,
            base.makespan
        );
    }
}

fn main() {
    if !smoke() {
        plan_cost();
    }
    println!("\n== {{queue}} x {{alloc}} x {{horizon}} identity, all five policies ==");
    policy_identity();
    let mxsched = engine_events_per_sec();
    let fair = fair_events_per_sec();
    let parallel = parallel_events_per_sec();
    write_bench_json(
        "sched_scaling",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke())),
            ("mxsched_priority", mxsched),
            ("fair", fair),
            ("parallel", parallel),
        ]),
    );
    println!("\nwrote BENCH_sim.json (section `sched_scaling`)");
}
