//! Fig. 6 — DDL layer-wise parameter synchronisation. The MXDAG
//! critical-path analysis recovers ByteScheduler's lowest-layer-first
//! transmission order; this bench sweeps depth and comm/compute ratio
//! and regenerates the iteration-time comparison vs FIFO order.

use mxdag::mxdag::cpm;
use mxdag::sched::{run, FairScheduler, FifoScheduler, MxScheduler};
use mxdag::sim::Cluster;
use mxdag::util::bench::Table;
use mxdag::workloads::{ddl_dag, DdlParams};

fn main() {
    let cluster = Cluster::with_cores(2, 2.0);

    let mut t = Table::new(
        "Fig 6 — iteration time by depth (bp=0.5, fp=2, comm=1)",
        &["fifo", "fair", "mxdag", "fifo/mxdag"],
    );
    for layers in [2usize, 4, 8, 16] {
        let (g, _) = ddl_dag(&DdlParams { layers, ..Default::default() });
        let fifo = run(&FifoScheduler, &g, &cluster).unwrap().makespan;
        let fair = run(&FairScheduler, &g, &cluster).unwrap().makespan;
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        t.row_f64(&format!("{layers} layers"), &[fifo, fair, mx, fifo / mx]);
        assert!(mx <= fifo + 1e-9, "mxdag must not lose to fifo");
    }
    t.print();

    let mut t = Table::new(
        "comm/compute sweep (4 layers)",
        &["fifo", "mxdag", "speedup"],
    );
    for comm in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let (g, _) = ddl_dag(&DdlParams { comm, ..Default::default() });
        let fifo = run(&FifoScheduler, &g, &cluster).unwrap().makespan;
        let mx = run(&MxScheduler::without_pipelining(), &g, &cluster)
            .unwrap()
            .makespan;
        t.row_f64(&format!("comm={comm}"), &[fifo, mx, fifo / mx]);
    }
    t.print();

    // sanity: the critical path goes through layer 0's sync
    let (g, layers) = ddl_dag(&DdlParams::default());
    let c = cpm(&g);
    assert!(c.is_critical(layers[0].push));
    println!("\ncritical path pins layer-0 push/pull (ByteScheduler order recovered)");
}
