//! PJRT runtime latency: artifact execution cost on the coordinator hot
//! path (L3 §Perf: the trainer step should be dominated by this compute,
//! not by coordination). Skips gracefully when artifacts are missing.

use std::path::Path;

use mxdag::runtime::{Engine, Tensor};
use mxdag::util::bench::{bench, bench_header};

fn main() {
    let dir = Path::new("artifacts");
    let engine = match Engine::load(dir) {
        Ok(e) => e,
        Err(e) => {
            println!("SKIP runtime_exec (run `make artifacts`): {e:#}");
            return;
        }
    };
    println!("platform: {}", engine.platform());
    let m = engine.manifest.clone();

    bench_header("artifact execution latency");

    // matmul
    let spec = &m.artifact("matmul").unwrap().inputs;
    let x = Tensor::f32(&spec[0].shape, vec![1.0; spec[0].elements()]);
    let w = Tensor::f32(&spec[1].shape, vec![1.0; spec[1].elements()]);
    bench("matmul (pallas tile kernel)", || {
        engine.execute("matmul", &[x.clone(), w.clone()]).unwrap();
    });

    // per-layer forwards
    for l in 0..m.model.n_layers {
        let name = format!("layer_fwd_{l}");
        let spec = &m.artifact(&name).unwrap().inputs;
        let inputs: Vec<Tensor> = spec
            .iter()
            .map(|s| Tensor::f32(&s.shape, vec![0.01; s.elements()]))
            .collect();
        bench(&name, || {
            engine.execute(&name, &inputs).unwrap();
        });
    }

    // grad step (the DDL worker hot path)
    let params = mxdag::coordinator::ddl::init_params(&m.model.param_shapes, 0);
    let gen = mxdag::coordinator::ddl::DataGen::new(
        m.model.input_dim,
        m.model.classes,
        m.model.batch,
        0,
    );
    let (xb, yb) = gen.batch(0, 0);
    let mut inputs = params.clone();
    inputs.push(xb);
    inputs.push(yb);
    bench("grad_step (fwd+bwd, full model)", || {
        engine.execute("grad_step", &inputs).unwrap();
    });

    // tensor conversion overhead (coordination tax)
    let big = Tensor::f32(&[784, 256], vec![0.5; 784 * 256]);
    bench("to_literal+from_literal 800KB", || {
        let l = mxdag::runtime::to_literal(&big).unwrap();
        let _ = mxdag::runtime::from_literal_f32(&l).unwrap();
    });
}
