//! Cluster-dynamics throughput — what fabric churn costs the engine's
//! fast paths. Three regimes per workload size, all on the
//! incremental-queue + component-allocation corner:
//!
//! 1. **frozen** — empty timeline (the pre-dynamics cost profile; the
//!    engine must not pay for churn it isn't experiencing),
//! 2. **churn** — a seeded random timeline of degradations, restores
//!    and stragglers spread across the run,
//! 3. **flap** — a link degrading/restoring on a period far denser
//!    than the task event rate, so nearly every step is a dynamics
//!    boundary (the worst case for the step-0 rescan).
//!
//! Oracles run on every invocation, before timing: under the churn
//! timeline every corner of the {queue} × {alloc} × {horizon} matrix ×
//! threads ∈ {1, 4} must match the serial whole-set oracle —
//! bit-identical events/makespan/traces on the eager corners, within
//! the shared 1e-6 tolerance on anchored — and the frozen run must be
//! bit-identical to a `SimConfig` that never mentions dynamics at all.
//! `BENCH_SMOKE=1` (the CI bench-smoke job) shrinks sizes and still
//! runs every oracle.
//!
//! A second sweep prices the fault-recovery layer (`sim/recovery.rs`)
//! under a crash/restore cycle: **failfast** (crashes are pure
//! capacity events — the PR 7 cost profile), **retry** (the same
//! timeline with in-flight victims killed, backoff-gated and re-run)
//! and **storm** (permanent host deaths quarantining whole jobs while
//! the survivors keep simulating). Its oracle — the same full matrix,
//! NaN-aware for quarantined traces, retry accounting compared
//! bitwise on the eager corners — also runs before any timing.
//!
//! Results are printed as tables (README §Performance) and persisted
//! to `BENCH_sim.json` (section `churn_sweep`) for cross-PR tracking.

use std::time::Instant;

use mxdag::sim::{
    expand, simulate, within_tolerance, AllocKind, Annotations, Cluster, DynAction, DynTimeline,
    HorizonKind, LinkRef, QueueKind, RecoveryPolicy, SimConfig, SimDag, SimResult,
};
use mxdag::util::bench::{write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::workloads::{random_dag, RandomParams};

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn shapes() -> Vec<(usize, usize)> {
    if smoke() {
        vec![(4, 4)]
    } else {
        vec![(10, 10), (16, 16), (24, 24)]
    }
}

/// Best-of-`reps` wall time for `f` (which must be pure).
fn timed<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

const MATRIX: [(QueueKind, AllocKind, HorizonKind); 8] = [
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
];

fn run(sim: &SimDag, cluster: &Cluster, cfg: &SimConfig) -> SimResult {
    simulate(sim, cluster, cfg).expect("bench workload must complete")
}

/// The full-matrix churn oracle (untimed): every corner × threads
/// {1, 4} against the serial whole-set baseline.
fn churn_oracle(sim: &SimDag, cluster: &Cluster, timeline: &DynTimeline) {
    let mk = |(queue, alloc, horizon): (QueueKind, AllocKind, HorizonKind), threads| SimConfig {
        queue,
        alloc,
        horizon,
        threads,
        dynamics: timeline.clone(),
        ..Default::default()
    };
    let base = run(sim, cluster, &mk(MATRIX[0], 1));
    for &corner in MATRIX.iter() {
        for threads in [1usize, 4] {
            let r = run(sim, cluster, &mk(corner, threads));
            let tag = format!("{corner:?} t{threads}");
            match corner.2 {
                HorizonKind::Eager => {
                    assert_eq!(base.events, r.events, "{tag}: event count");
                    assert_eq!(
                        base.makespan.to_bits(),
                        r.makespan.to_bits(),
                        "{tag}: makespan"
                    );
                    for (i, (a, b)) in base.trace.iter().zip(r.trace.iter()).enumerate() {
                        assert_eq!(a.start.to_bits(), b.start.to_bits(), "{tag}: chunk {i}");
                        assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "{tag}: chunk {i}");
                    }
                }
                HorizonKind::Anchored => {
                    assert!(
                        within_tolerance(base.makespan, r.makespan),
                        "{tag}: makespan {} vs {}",
                        base.makespan,
                        r.makespan
                    );
                    for (i, (a, b)) in base.trace.iter().zip(r.trace.iter()).enumerate() {
                        assert!(
                            within_tolerance(a.start, b.start)
                                && within_tolerance(a.finish, b.finish),
                            "{tag}: chunk {i}"
                        );
                    }
                }
            }
        }
    }
}

fn churn_sweep() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "churn sweep events/s (frozen cluster vs random churn vs flap storm)",
        &["events", "dyn evts", "frozen", "churn", "flap", "churn/frozen"],
    );
    let mut rows = Vec::new();
    for (layers, width) in shapes() {
        let p = RandomParams { layers, width, hosts, seed: 47, ..Default::default() };
        let g = random_dag(&p);
        let sim = expand(&g, &Default::default());
        let fast = SimConfig {
            queue: QueueKind::Incremental,
            alloc: AllocKind::Components,
            ..Default::default()
        };

        // the frozen baseline also sizes the timelines: churn events
        // are spread over the first 90% of the run, the flap period is
        // a small fraction of the makespan
        let frozen = run(&sim, &cluster, &fast);
        let n_dyn = if smoke() { 8 } else { 64 };
        let churn = DynTimeline::random(0xC0FE ^ g.len() as u64, &cluster, n_dyn, frozen.makespan * 0.9);
        let flap_period = frozen.makespan / if smoke() { 20.0 } else { 200.0 };
        let flap = DynTimeline::flap(LinkRef::NicUp(0), 0.3, flap_period, frozen.makespan);

        // -- oracles first (untimed)
        // an explicitly-empty timeline must be bit-identical to the
        // default config: the engine pays nothing for churn it isn't
        // experiencing
        let with_empty = run(
            &sim,
            &cluster,
            &SimConfig { dynamics: DynTimeline::new(), ..fast.clone() },
        );
        assert_eq!(frozen.events, with_empty.events, "empty timeline must be free");
        assert_eq!(frozen.makespan.to_bits(), with_empty.makespan.to_bits());
        churn_oracle(&sim, &cluster, &churn);
        churn_oracle(&sim, &cluster, &flap);

        // -- timings
        let reps = if smoke() { 1 } else { 3 };
        let churn_cfg = SimConfig { dynamics: churn.clone(), ..fast.clone() };
        let flap_cfg = SimConfig { dynamics: flap.clone(), ..fast.clone() };
        let r_churn = run(&sim, &cluster, &churn_cfg);
        let r_flap = run(&sim, &cluster, &flap_cfg);
        let t_frozen = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &fast).makespan);
        });
        let t_churn = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &churn_cfg).makespan);
        });
        let t_flap = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &flap_cfg).makespan);
        });
        let evps_frozen = frozen.events as f64 / t_frozen;
        let evps_churn = r_churn.events as f64 / t_churn;
        let evps_flap = r_flap.events as f64 / t_flap;
        table.row(
            &format!("{} tasks", g.real_tasks().count()),
            &[
                format!("{}", frozen.events),
                format!("{}", churn.len() + flap.len()),
                format!("{evps_frozen:.0}"),
                format!("{evps_churn:.0}"),
                format!("{evps_flap:.0}"),
                format!("{:.2}x", t_churn / t_frozen),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(g.real_tasks().count() as f64)),
            ("events_frozen", Json::Num(frozen.events as f64)),
            ("events_churn", Json::Num(r_churn.events as f64)),
            ("events_flap", Json::Num(r_flap.events as f64)),
            ("dyn_events_churn", Json::Num(churn.len() as f64)),
            ("dyn_events_flap", Json::Num(flap.len() as f64)),
            ("events_per_sec_frozen", Json::Num(evps_frozen)),
            ("events_per_sec_churn", Json::Num(evps_churn)),
            ("events_per_sec_flap", Json::Num(evps_flap)),
            ("overhead_churn_vs_frozen", Json::Num(t_churn / t_frozen)),
            ("overhead_flap_vs_frozen", Json::Num(t_flap / t_frozen)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

/// The recovery matrix oracle (untimed): every corner × threads
/// {1, 4} under `policy` against the serial whole-set baseline —
/// NaN-aware (quarantined chunks keep NaN traces everywhere), with
/// the discrete recovery outputs (retry count, per-job outcome kinds)
/// compared exactly on the bitwise corners.
fn recovery_oracle(
    sim: &SimDag,
    cluster: &Cluster,
    timeline: &DynTimeline,
    policy: RecoveryPolicy,
) {
    let mk = |(queue, alloc, horizon): (QueueKind, AllocKind, HorizonKind), threads| SimConfig {
        queue,
        alloc,
        horizon,
        threads,
        dynamics: timeline.clone(),
        recovery: policy,
        ..Default::default()
    };
    let base = run(sim, cluster, &mk(MATRIX[0], 1));
    for &corner in MATRIX.iter() {
        for threads in [1usize, 4] {
            let r = run(sim, cluster, &mk(corner, threads));
            let tag = format!("recovery {corner:?} t{threads}");
            assert_eq!(base.jobs.len(), r.jobs.len(), "{tag}: job count");
            for (j, (a, b)) in base.jobs.iter().zip(r.jobs.iter()).enumerate() {
                assert_eq!(
                    a.is_completed(),
                    b.is_completed(),
                    "{tag}: job {j} outcome {a:?} vs {b:?}"
                );
            }
            match corner.2 {
                HorizonKind::Eager => {
                    assert_eq!(base.events, r.events, "{tag}: event count");
                    assert_eq!(base.retries, r.retries, "{tag}: retries");
                    assert_eq!(
                        base.makespan.to_bits(),
                        r.makespan.to_bits(),
                        "{tag}: makespan"
                    );
                    for (i, (a, b)) in base.trace.iter().zip(r.trace.iter()).enumerate() {
                        assert_eq!(a.start.to_bits(), b.start.to_bits(), "{tag}: chunk {i}");
                        assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "{tag}: chunk {i}");
                    }
                }
                HorizonKind::Anchored => {
                    assert!(
                        within_tolerance(base.makespan, r.makespan),
                        "{tag}: makespan {} vs {}",
                        base.makespan,
                        r.makespan
                    );
                    let ok = |x: f64, y: f64| {
                        within_tolerance(x, y) || (x.is_nan() && y.is_nan())
                    };
                    for (i, (a, b)) in base.trace.iter().zip(r.trace.iter()).enumerate() {
                        assert!(
                            ok(a.start, b.start) && ok(a.finish, b.finish),
                            "{tag}: chunk {i}"
                        );
                    }
                }
            }
        }
    }
}

fn recovery_sweep() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let n_jobs = 8usize;
    let mut table = Table::new(
        "recovery sweep events/s (failfast vs retry vs quarantine storm)",
        &["events", "failfast", "retry", "storm", "retries", "quarantined", "retry/failfast"],
    );
    let mut rows = Vec::new();
    for (layers, width) in shapes() {
        let p = RandomParams { layers, width, hosts, seed: 47, ..Default::default() };
        let g = random_dag(&p);
        // round-robin job map: the quarantine unit for the storm regime
        let mut ann = Annotations::default();
        for (i, t) in g.real_tasks().enumerate() {
            ann.jobs.insert(t, i % n_jobs);
        }
        let sim = expand(&g, &ann);
        let fast = SimConfig {
            queue: QueueKind::Incremental,
            alloc: AllocKind::Components,
            ..Default::default()
        };
        let frozen = run(&sim, &cluster, &fast);
        let mk = frozen.makespan;

        // two crash/restore cycles, sized to land mid-run: recoverable
        // under both policies (FailFast stalls through the outage,
        // Retry re-runs the victims), so the regimes are comparable
        let cycle = DynTimeline::new()
            .with(mk * 0.31, DynAction::FailHost { host: 0 })
            .with(mk * 0.47, DynAction::RestoreHost { host: 0 })
            .with(mk * 0.55, DynAction::FailHost { host: 1 })
            .with(mk * 0.71, DynAction::RestoreHost { host: 1 });
        // the storm: hosts 0-2 die for good — their jobs exhaust or
        // starve and are quarantined while the rest keeps simulating
        let storm = DynTimeline::new()
            .with(mk * 0.23, DynAction::FailHost { host: 0 })
            .with(mk * 0.37, DynAction::FailHost { host: 1 })
            .with(mk * 0.53, DynAction::FailHost { host: 2 });
        let retry = RecoveryPolicy::Retry { max_attempts: 5, backoff: mk * 0.02 };
        let storm_policy = RecoveryPolicy::Retry { max_attempts: 2, backoff: mk * 0.02 };

        // -- oracles first (untimed)
        recovery_oracle(&sim, &cluster, &cycle, RecoveryPolicy::FailFast);
        recovery_oracle(&sim, &cluster, &cycle, retry);
        recovery_oracle(&sim, &cluster, &storm, storm_policy);

        // -- timings
        let reps = if smoke() { 1 } else { 3 };
        let ff_cfg = SimConfig { dynamics: cycle.clone(), ..fast.clone() };
        let retry_cfg =
            SimConfig { dynamics: cycle.clone(), recovery: retry, ..fast.clone() };
        let storm_cfg =
            SimConfig { dynamics: storm.clone(), recovery: storm_policy, ..fast.clone() };
        let r_ff = run(&sim, &cluster, &ff_cfg);
        let r_retry = run(&sim, &cluster, &retry_cfg);
        let r_storm = run(&sim, &cluster, &storm_cfg);
        let quarantined = r_storm.jobs.iter().filter(|j| !j.is_completed()).count();
        let t_ff = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &ff_cfg).makespan);
        });
        let t_retry = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &retry_cfg).makespan);
        });
        let t_storm = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &storm_cfg).makespan);
        });
        let evps_ff = r_ff.events as f64 / t_ff;
        let evps_retry = r_retry.events as f64 / t_retry;
        let evps_storm = r_storm.events as f64 / t_storm;
        table.row(
            &format!("{} tasks", g.real_tasks().count()),
            &[
                format!("{}", r_ff.events),
                format!("{evps_ff:.0}"),
                format!("{evps_retry:.0}"),
                format!("{evps_storm:.0}"),
                format!("{}", r_retry.retries),
                format!("{quarantined}/{n_jobs}"),
                format!("{:.2}x", t_retry / t_ff),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(g.real_tasks().count() as f64)),
            ("jobs", Json::Num(n_jobs as f64)),
            ("events_failfast", Json::Num(r_ff.events as f64)),
            ("events_retry", Json::Num(r_retry.events as f64)),
            ("events_storm", Json::Num(r_storm.events as f64)),
            ("retries_retry", Json::Num(r_retry.retries as f64)),
            ("retries_storm", Json::Num(r_storm.retries as f64)),
            ("quarantined_storm", Json::Num(quarantined as f64)),
            ("lost_work_storm", Json::Num(r_storm.lost_work)),
            ("events_per_sec_failfast", Json::Num(evps_ff)),
            ("events_per_sec_retry", Json::Num(evps_retry)),
            ("events_per_sec_storm", Json::Num(evps_storm)),
            ("overhead_retry_vs_failfast", Json::Num(t_retry / t_ff)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn main() {
    println!("== full-matrix churn + recovery oracles run before every timing ==");
    let rows = churn_sweep();
    let recovery_rows = recovery_sweep();
    write_bench_json(
        "churn_sweep",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke())),
            ("rows", rows),
            ("recovery", recovery_rows),
        ]),
    );
    println!("\nwrote BENCH_sim.json (section `churn_sweep`)");
}
