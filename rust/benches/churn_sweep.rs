//! Cluster-dynamics throughput — what fabric churn costs the engine's
//! fast paths. Three regimes per workload size, all on the
//! incremental-queue + component-allocation corner:
//!
//! 1. **frozen** — empty timeline (the pre-dynamics cost profile; the
//!    engine must not pay for churn it isn't experiencing),
//! 2. **churn** — a seeded random timeline of degradations, restores
//!    and stragglers spread across the run,
//! 3. **flap** — a link degrading/restoring on a period far denser
//!    than the task event rate, so nearly every step is a dynamics
//!    boundary (the worst case for the step-0 rescan).
//!
//! Oracles run on every invocation, before timing: under the churn
//! timeline every corner of the {queue} × {alloc} × {horizon} matrix ×
//! threads ∈ {1, 4} must match the serial whole-set oracle —
//! bit-identical events/makespan/traces on the eager corners, within
//! the shared 1e-6 tolerance on anchored — and the frozen run must be
//! bit-identical to a `SimConfig` that never mentions dynamics at all.
//! `BENCH_SMOKE=1` (the CI bench-smoke job) shrinks sizes and still
//! runs every oracle.
//!
//! Results are printed as tables (README §Performance) and persisted
//! to `BENCH_sim.json` (section `churn_sweep`) for cross-PR tracking.

use std::time::Instant;

use mxdag::sim::{
    expand, simulate, within_tolerance, AllocKind, Cluster, DynTimeline, HorizonKind, LinkRef,
    QueueKind, SimConfig, SimDag, SimResult,
};
use mxdag::util::bench::{write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::workloads::{random_dag, RandomParams};

fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn shapes() -> Vec<(usize, usize)> {
    if smoke() {
        vec![(4, 4)]
    } else {
        vec![(10, 10), (16, 16), (24, 24)]
    }
}

/// Best-of-`reps` wall time for `f` (which must be pure).
fn timed<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

const MATRIX: [(QueueKind, AllocKind, HorizonKind); 8] = [
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Eager),
    (QueueKind::FullResort, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::WholeSet, HorizonKind::Anchored),
    (QueueKind::FullResort, AllocKind::Components, HorizonKind::Anchored),
    (QueueKind::Incremental, AllocKind::Components, HorizonKind::Anchored),
];

fn run(sim: &SimDag, cluster: &Cluster, cfg: &SimConfig) -> SimResult {
    simulate(sim, cluster, cfg).expect("bench workload must complete")
}

/// The full-matrix churn oracle (untimed): every corner × threads
/// {1, 4} against the serial whole-set baseline.
fn churn_oracle(sim: &SimDag, cluster: &Cluster, timeline: &DynTimeline) {
    let mk = |(queue, alloc, horizon): (QueueKind, AllocKind, HorizonKind), threads| SimConfig {
        queue,
        alloc,
        horizon,
        threads,
        dynamics: timeline.clone(),
        ..Default::default()
    };
    let base = run(sim, cluster, &mk(MATRIX[0], 1));
    for &corner in MATRIX.iter() {
        for threads in [1usize, 4] {
            let r = run(sim, cluster, &mk(corner, threads));
            let tag = format!("{corner:?} t{threads}");
            match corner.2 {
                HorizonKind::Eager => {
                    assert_eq!(base.events, r.events, "{tag}: event count");
                    assert_eq!(
                        base.makespan.to_bits(),
                        r.makespan.to_bits(),
                        "{tag}: makespan"
                    );
                    for (i, (a, b)) in base.trace.iter().zip(r.trace.iter()).enumerate() {
                        assert_eq!(a.start.to_bits(), b.start.to_bits(), "{tag}: chunk {i}");
                        assert_eq!(a.finish.to_bits(), b.finish.to_bits(), "{tag}: chunk {i}");
                    }
                }
                HorizonKind::Anchored => {
                    assert!(
                        within_tolerance(base.makespan, r.makespan),
                        "{tag}: makespan {} vs {}",
                        base.makespan,
                        r.makespan
                    );
                    for (i, (a, b)) in base.trace.iter().zip(r.trace.iter()).enumerate() {
                        assert!(
                            within_tolerance(a.start, b.start)
                                && within_tolerance(a.finish, b.finish),
                            "{tag}: chunk {i}"
                        );
                    }
                }
            }
        }
    }
}

fn churn_sweep() -> Json {
    let hosts = 16;
    let cluster = Cluster::uniform(hosts);
    let mut table = Table::new(
        "churn sweep events/s (frozen cluster vs random churn vs flap storm)",
        &["events", "dyn evts", "frozen", "churn", "flap", "churn/frozen"],
    );
    let mut rows = Vec::new();
    for (layers, width) in shapes() {
        let p = RandomParams { layers, width, hosts, seed: 47, ..Default::default() };
        let g = random_dag(&p);
        let sim = expand(&g, &Default::default());
        let fast = SimConfig {
            queue: QueueKind::Incremental,
            alloc: AllocKind::Components,
            ..Default::default()
        };

        // the frozen baseline also sizes the timelines: churn events
        // are spread over the first 90% of the run, the flap period is
        // a small fraction of the makespan
        let frozen = run(&sim, &cluster, &fast);
        let n_dyn = if smoke() { 8 } else { 64 };
        let churn = DynTimeline::random(0xC0FE ^ g.len() as u64, &cluster, n_dyn, frozen.makespan * 0.9);
        let flap_period = frozen.makespan / if smoke() { 20.0 } else { 200.0 };
        let flap = DynTimeline::flap(LinkRef::NicUp(0), 0.3, flap_period, frozen.makespan);

        // -- oracles first (untimed)
        // an explicitly-empty timeline must be bit-identical to the
        // default config: the engine pays nothing for churn it isn't
        // experiencing
        let with_empty = run(
            &sim,
            &cluster,
            &SimConfig { dynamics: DynTimeline::new(), ..fast.clone() },
        );
        assert_eq!(frozen.events, with_empty.events, "empty timeline must be free");
        assert_eq!(frozen.makespan.to_bits(), with_empty.makespan.to_bits());
        churn_oracle(&sim, &cluster, &churn);
        churn_oracle(&sim, &cluster, &flap);

        // -- timings
        let reps = if smoke() { 1 } else { 3 };
        let churn_cfg = SimConfig { dynamics: churn.clone(), ..fast.clone() };
        let flap_cfg = SimConfig { dynamics: flap.clone(), ..fast.clone() };
        let r_churn = run(&sim, &cluster, &churn_cfg);
        let r_flap = run(&sim, &cluster, &flap_cfg);
        let t_frozen = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &fast).makespan);
        });
        let t_churn = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &churn_cfg).makespan);
        });
        let t_flap = timed(reps, || {
            std::hint::black_box(run(&sim, &cluster, &flap_cfg).makespan);
        });
        let evps_frozen = frozen.events as f64 / t_frozen;
        let evps_churn = r_churn.events as f64 / t_churn;
        let evps_flap = r_flap.events as f64 / t_flap;
        table.row(
            &format!("{} tasks", g.real_tasks().count()),
            &[
                format!("{}", frozen.events),
                format!("{}", churn.len() + flap.len()),
                format!("{evps_frozen:.0}"),
                format!("{evps_churn:.0}"),
                format!("{evps_flap:.0}"),
                format!("{:.2}x", t_churn / t_frozen),
            ],
        );
        rows.push(Json::obj(vec![
            ("tasks", Json::Num(g.real_tasks().count() as f64)),
            ("events_frozen", Json::Num(frozen.events as f64)),
            ("events_churn", Json::Num(r_churn.events as f64)),
            ("events_flap", Json::Num(r_flap.events as f64)),
            ("dyn_events_churn", Json::Num(churn.len() as f64)),
            ("dyn_events_flap", Json::Num(flap.len() as f64)),
            ("events_per_sec_frozen", Json::Num(evps_frozen)),
            ("events_per_sec_churn", Json::Num(evps_churn)),
            ("events_per_sec_flap", Json::Num(evps_flap)),
            ("overhead_churn_vs_frozen", Json::Num(t_churn / t_frozen)),
            ("overhead_flap_vs_frozen", Json::Num(t_flap / t_frozen)),
        ]));
    }
    table.print();
    Json::Arr(rows)
}

fn main() {
    println!("== full-matrix churn oracles run before every timing ==");
    let rows = churn_sweep();
    write_bench_json(
        "churn_sweep",
        Json::obj(vec![("smoke", Json::Bool(smoke())), ("rows", rows)]),
    );
    println!("\nwrote BENCH_sim.json (section `churn_sweep`)");
}
