//! Simulator capability bench: events/second and wall time vs DAG size
//! — the L3 §Perf target (≥1e6 events/s on figure-scale DAGs).

use std::time::Instant;

use mxdag::sched::{evaluate, Plan};
use mxdag::sim::Cluster;
use mxdag::util::bench::{bench, bench_header, Table};
use mxdag::workloads::{random_dag, RandomParams};

fn main() {
    let mut t = Table::new(
        "fluid simulator scaling",
        &["tasks", "events", "wall µs", "events/s"],
    );
    for (layers, width) in [(4usize, 4usize), (8, 8), (12, 12), (16, 16), (20, 20)] {
        let p = RandomParams {
            layers,
            width,
            hosts: 16,
            seed: 42,
            ..Default::default()
        };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(16);
        let plan = Plan::fair();
        // measure
        let t0 = Instant::now();
        let mut events = 0usize;
        let mut iters = 0u32;
        while t0.elapsed().as_millis() < 200 {
            events += evaluate(&g, &cluster, &plan).unwrap().events;
            iters += 1;
        }
        let wall_us = t0.elapsed().as_micros() as f64 / iters as f64;
        let ev = events as f64 / iters as f64;
        t.row(
            &format!("{layers}x{width}"),
            &[
                format!("{}", g.real_tasks().count()),
                format!("{ev:.0}"),
                format!("{wall_us:.0}"),
                format!("{:.2e}", ev / (wall_us / 1e6)),
            ],
        );
    }
    t.print();

    bench_header("per-policy simulation cost (12x12 DAG)");
    let g = random_dag(&RandomParams { layers: 12, width: 12, hosts: 16, seed: 7, ..Default::default() });
    let cluster = Cluster::uniform(16);
    for (name, plan) in [
        ("fair", Plan::fair()),
        ("priority", Plan { ann: Default::default(), policy: mxdag::sim::Policy::priority() }),
        ("fifo", Plan { ann: Default::default(), policy: mxdag::sim::Policy::fifo() }),
        ("coflow", Plan { ann: Default::default(), policy: mxdag::sim::Policy::coflow() }),
    ] {
        bench(name, || {
            evaluate(&g, &cluster, &plan).unwrap();
        });
    }
}
