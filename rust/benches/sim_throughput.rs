//! Simulator capability bench: events/second and wall time vs DAG size
//! — the L3 §Perf target (≥1e6 events/s on figure-scale DAGs). Results
//! are persisted to `BENCH_sim.json` (section `sim_throughput`) so the
//! perf trajectory is tracked across PRs, not only printed.

use std::time::Instant;

use mxdag::sched::{evaluate, evaluate_with, Plan};
use mxdag::sim::{Cluster, HorizonKind, SimConfig};
use mxdag::util::bench::{bench, bench_header, write_bench_json, Table};
use mxdag::util::json::Json;
use mxdag::workloads::{random_dag, RandomParams};

/// Time `evaluate_with` under `horizon` for ~200 ms; returns
/// (mean events per run, mean wall µs per run).
fn timed(g: &mxdag::mxdag::MXDag, cluster: &Cluster, horizon: HorizonKind) -> (f64, f64) {
    let plan = Plan::fair();
    let cfg = SimConfig { horizon, ..Default::default() };
    let t0 = Instant::now();
    let mut events = 0usize;
    let mut iters = 0u32;
    while t0.elapsed().as_millis() < 200 {
        events += evaluate_with(g, cluster, &plan, &cfg).unwrap().events;
        iters += 1;
    }
    let wall_us = t0.elapsed().as_micros() as f64 / iters as f64;
    (events as f64 / iters as f64, wall_us)
}

fn main() {
    let mut t = Table::new(
        "fluid simulator scaling (eager integration vs anchored horizon)",
        &["tasks", "events", "eager ev/s", "anchored ev/s", "anch/eager"],
    );
    let mut rows = Vec::new();
    for (layers, width) in [(4usize, 4usize), (8, 8), (12, 12), (16, 16), (20, 20)] {
        let p = RandomParams {
            layers,
            width,
            hosts: 16,
            seed: 42,
            ..Default::default()
        };
        let g = random_dag(&p);
        let cluster = Cluster::uniform(16);
        let (ev_eager, wall_eager) = timed(&g, &cluster, HorizonKind::Eager);
        let (ev_anch, wall_anch) = timed(&g, &cluster, HorizonKind::Anchored);
        let tasks = g.real_tasks().count();
        let evps_eager = ev_eager / (wall_eager / 1e6);
        let evps_anch = ev_anch / (wall_anch / 1e6);
        t.row(
            &format!("{layers}x{width}"),
            &[
                format!("{tasks}"),
                format!("{ev_eager:.0}"),
                format!("{evps_eager:.2e}"),
                format!("{evps_anch:.2e}"),
                format!("{:.1}x", evps_anch / evps_eager),
            ],
        );
        rows.push(Json::obj(vec![
            ("config", Json::Str(format!("{layers}x{width}"))),
            ("tasks", Json::Num(tasks as f64)),
            ("events", Json::Num(ev_eager)),
            ("events_anchored", Json::Num(ev_anch)),
            ("wall_us", Json::Num(wall_eager)),
            ("wall_us_anchored", Json::Num(wall_anch)),
            ("events_per_sec", Json::Num(evps_eager)),
            ("events_per_sec_anchored", Json::Num(evps_anch)),
        ]));
    }
    t.print();
    write_bench_json("sim_throughput", Json::Arr(rows));
    println!("\nwrote BENCH_sim.json (section `sim_throughput`)");

    bench_header("per-policy simulation cost (12x12 DAG)");
    let g = random_dag(&RandomParams { layers: 12, width: 12, hosts: 16, seed: 7, ..Default::default() });
    let cluster = Cluster::uniform(16);
    for (name, plan) in [
        ("fair", Plan::fair()),
        ("priority", Plan { ann: Default::default(), policy: mxdag::sim::Policy::priority() }),
        ("fifo", Plan { ann: Default::default(), policy: mxdag::sim::Policy::fifo() }),
        ("coflow", Plan { ann: Default::default(), policy: mxdag::sim::Policy::coflow() }),
    ] {
        bench(name, || {
            evaluate(&g, &cluster, &plan).unwrap();
        });
    }
}
